//! SEQ — Stretched Elastic Quantization (paper §2.1.2).
//!
//! Symmetric 2-bit mapping {-1.5, -0.5, +0.5, +1.5} * scale: no zero level,
//! shifted centroid, full dynamic-range coverage. Mirrors the python-side
//! reference (kernels/ref.py quantize_seq2) bit-for-bit so codes can move
//! between the two worlds. Includes the "adaptive micro-tuning of the
//! scaling factor" step: a small 1-D search refining the absmax scale to
//! minimize group MSE.

use super::WeightQuantizer;

#[derive(Clone, Debug)]
pub struct Seq2Quantizer {
    pub group: usize,
    /// enable scale micro-tuning (paper: adaptive micro-tuning of the
    /// scaling factor for quantization intervals)
    pub tune_scale: bool,
}

impl Seq2Quantizer {
    pub fn new(group: usize) -> Self {
        Seq2Quantizer { group, tune_scale: false }
    }

    pub fn tuned(group: usize) -> Self {
        Seq2Quantizer { group, tune_scale: true }
    }

    /// level for a code 0..=3
    #[inline]
    pub fn level(code: u8) -> f32 {
        (2.0 * code as f32 - 3.0) * 0.5
    }

    /// code for a value already divided by scale: nearest level is
    /// round(v + 1.5) since level(c) = c - 1.5
    #[inline]
    pub fn encode_unit(v: f32) -> u8 {
        ((v + 1.5).round().clamp(0.0, 3.0)) as u8
    }

    fn group_scale(&self, xs: &[f32]) -> f32 {
        let absmax = xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        let base = if absmax == 0.0 { 1.0 } else { absmax / 1.5 };
        if !self.tune_scale {
            return base;
        }
        // micro-tune: grid around the absmax scale, pick min-MSE
        let mut best = base;
        let mut best_mse = f32::INFINITY;
        for mult in [0.7, 0.8, 0.9, 1.0, 1.1] {
            let s = base * mult;
            let mse: f32 = xs
                .iter()
                .map(|&x| {
                    let q = Self::level(Self::encode_unit(x / s)) * s;
                    (q - x) * (q - x)
                })
                .sum();
            if mse < best_mse {
                best_mse = mse;
                best = s;
            }
        }
        best
    }

    /// Quantize to (codes, per-group scales).
    pub fn quantize_codes(&self, w: &[f32], n: usize, k: usize) -> (Vec<u8>, Vec<f32>) {
        assert_eq!(w.len(), n * k);
        assert!(k % self.group == 0);
        let mut codes = vec![0u8; n * k];
        let mut scales = Vec::with_capacity(n * k / self.group);
        for row in 0..n {
            for gs in (0..k).step_by(self.group) {
                let sl = &w[row * k + gs..row * k + gs + self.group];
                let s = self.group_scale(sl);
                scales.push(s);
                for (i, &x) in sl.iter().enumerate() {
                    codes[row * k + gs + i] = Self::encode_unit(x / s);
                }
            }
        }
        (codes, scales)
    }

    pub fn dequantize_codes(
        &self,
        codes: &[u8],
        scales: &[f32],
        n: usize,
        k: usize,
    ) -> Vec<f32> {
        let mut w = vec![0.0f32; n * k];
        for row in 0..n {
            for gs in (0..k).step_by(self.group) {
                let s = scales[(row * k + gs) / self.group];
                for i in 0..self.group {
                    w[row * k + gs + i] = Self::level(codes[row * k + gs + i]) * s;
                }
            }
        }
        w
    }
}

impl WeightQuantizer for Seq2Quantizer {
    fn name(&self) -> &'static str {
        "seq2"
    }

    fn bits(&self) -> f64 {
        2.0 + 32.0 / self.group as f64
    }

    fn qdq(&self, w: &mut [f32], n: usize, k: usize) {
        let (codes, scales) = self.quantize_codes(w, n, k);
        let deq = self.dequantize_codes(&codes, &scales, n, k);
        w.copy_from_slice(&deq);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testing, Rng};

    #[test]
    fn levels_symmetric_no_zero() {
        let ls: Vec<f32> = (0..4).map(Seq2Quantizer::level).collect();
        assert_eq!(ls, vec![-1.5, -0.5, 0.5, 1.5]);
        assert!(ls.iter().all(|&l| l != 0.0));
    }

    #[test]
    fn absmax_maps_to_extreme_level() {
        let q = Seq2Quantizer::new(4);
        let w = [0.1f32, -0.2, 0.3, -0.6];
        let (codes, scales) = q.quantize_codes(&w, 1, 4);
        // absmax 0.6 -> scale 0.4 -> -0.6/0.4 = -1.5 -> code 0
        assert!((scales[0] - 0.4).abs() < 1e-6);
        assert_eq!(codes[3], 0);
    }

    #[test]
    fn qdq_error_bounded() {
        testing::check(8, |rng| {
            let (n, k) = (8, 64);
            let orig = rng.normal_vec(n * k, 1.0);
            let mut w = orig.clone();
            let q = Seq2Quantizer::new(32);
            q.qdq(&mut w, n, k);
            // error <= half a level spacing = 0.5 * scale
            for row in 0..n {
                for gs in (0..k).step_by(32) {
                    let sl = &orig[row * k + gs..row * k + gs + 32];
                    let absmax = sl.iter().fold(0.0f32, |m, x| m.max(x.abs()));
                    let scale = absmax / 1.5;
                    for i in 0..32 {
                        let e = (w[row * k + gs + i] - sl[i]).abs();
                        assert!(e <= 0.5 * scale + 1e-6);
                    }
                }
            }
        });
    }

    #[test]
    fn tuned_scale_never_worse() {
        testing::check(16, |rng| {
            let (n, k) = (4, 32);
            let orig = rng.normal_vec(n * k, 0.7);
            let mut plain = orig.clone();
            let mut tuned = orig.clone();
            Seq2Quantizer::new(32).qdq(&mut plain, n, k);
            Seq2Quantizer::tuned(32).qdq(&mut tuned, n, k);
            let m_plain = crate::util::stats::mse(&plain, &orig);
            let m_tuned = crate::util::stats::mse(&tuned, &orig);
            assert!(m_tuned <= m_plain + 1e-9, "{m_tuned} vs {m_plain}");
        });
    }

    #[test]
    fn matches_python_reference_semantics() {
        // same example as kernels/ref.py convention: code = round(w/s + 1)
        let mut rng = Rng::new(3);
        let w = rng.normal_vec(64, 1.0);
        let q = Seq2Quantizer::new(32);
        let (codes, scales) = q.quantize_codes(&w, 1, 64);
        for (i, &c) in codes.iter().enumerate() {
            let s = scales[i / 32];
            let expect = ((w[i] / s + 1.5).round()).clamp(0.0, 3.0) as u8;
            assert_eq!(c, expect);
        }
    }
}
