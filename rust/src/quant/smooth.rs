//! SmoothQuant-style outlier migration — the "traditional smoothing" that
//! LeptoQuant's §2.3.2 analysis contrasts against: it shifts activation
//! outliers into weights via s_c = max|X_c|^α / max|W_c|^(1-α), trading
//! activation range for weight range.

use crate::tensor::Tensor;

#[derive(Clone, Debug)]
pub struct SmoothQuant {
    pub alpha: f32,
}

impl Default for SmoothQuant {
    fn default() -> Self {
        SmoothQuant { alpha: 0.5 }
    }
}

impl SmoothQuant {
    /// Compute per-channel migration scales from activation/weight ranges.
    pub fn scales(&self, x: &Tensor, w: &Tensor) -> Vec<f32> {
        self.shared_scales(x, &[w])
    }

    /// Migration scales shared by several linears reading the same input
    /// (wq/wk/wv after ln1, w_gate/w_up after ln2): the weight range is
    /// taken over *all* consumers so one scale vector serves them all —
    /// what the pipeline's `smooth` pass folds into the RMSNorm gains.
    pub fn shared_scales(&self, x: &Tensor, ws: &[&Tensor]) -> Vec<f32> {
        let k = x.cols();
        let mut xmax = vec![1e-6f32; k];
        for r in 0..x.rows() {
            for c in 0..k {
                xmax[c] = xmax[c].max(x.row(r)[c].abs());
            }
        }
        let mut wmax = vec![1e-6f32; k];
        for w in ws {
            assert_eq!(w.cols(), k);
            for r in 0..w.rows() {
                for c in 0..k {
                    wmax[c] = wmax[c].max(w.row(r)[c].abs());
                }
            }
        }
        (0..k)
            .map(|c| {
                (xmax[c].powf(self.alpha) / wmax[c].powf(1.0 - self.alpha)).max(1e-5)
            })
            .collect()
    }

    /// Apply migration: x'_c = x_c / s_c, w'_c = w_c * s_c.
    /// The product X'W'ᵀ is mathematically unchanged.
    pub fn apply(&self, x: &mut Tensor, w: &mut Tensor) -> Vec<f32> {
        let s = self.scales(x, w);
        for r in 0..x.rows() {
            let row = x.row_mut(r);
            for (c, sc) in s.iter().enumerate() {
                row[c] /= sc;
            }
        }
        for r in 0..w.rows() {
            let row = w.row_mut(r);
            for (c, sc) in s.iter().enumerate() {
                row[c] *= sc;
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_transb;
    use crate::util::{testing::assert_allclose, Rng};

    #[test]
    fn migration_preserves_product() {
        let mut rng = Rng::new(0);
        let mut x = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let mut w = Tensor::randn(&[16, 32], 0.5, &mut rng);
        let y_before = matmul_transb(&x, &w);
        SmoothQuant::default().apply(&mut x, &mut w);
        let y_after = matmul_transb(&x, &w);
        assert_allclose(&y_after.data, &y_before.data, 1e-4, 1e-4);
    }

    #[test]
    fn migration_shrinks_activation_outliers() {
        let mut rng = Rng::new(1);
        let mut x = Tensor::randn(&[16, 32], 1.0, &mut rng);
        for r in 0..16 {
            x.row_mut(r)[5] *= 50.0; // channel-5 outliers
        }
        let mut w = Tensor::randn(&[8, 32], 0.5, &mut rng);
        let before: f32 = (0..16).map(|r| x.row(r)[5].abs()).fold(0.0, f32::max);
        SmoothQuant::default().apply(&mut x, &mut w);
        let after: f32 = (0..16).map(|r| x.row(r)[5].abs()).fold(0.0, f32::max);
        assert!(after < before / 3.0, "{after} vs {before}");
    }

    #[test]
    fn alpha_zero_leaves_acts_mostly_untouched() {
        // alpha=0: s_c = 1 / wmax_c — activation ranges scale by wmax only
        let mut rng = Rng::new(2);
        let mut x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let mut w = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let s = SmoothQuant { alpha: 0.0 }.apply(&mut x, &mut w);
        for (c, sc) in s.iter().enumerate() {
            let wmax: f32 = (0..4).map(|r| (w.row(r)[c] / sc).abs()).fold(0.0, f32::max);
            assert!((sc - 1.0 / wmax.max(1e-6)).abs() / sc < 0.5);
        }
    }
}
