//! Sherry — hardware-efficient 1.25-bit ternary quantization via 3:4
//! fine-grained structured sparsity (paper §2.2.2).
//!
//! Constraint: exactly three non-zero (±1) weights in every contiguous
//! block of four. Each block then has C(4,3) * 2^3 = 32 configurations —
//! exactly a 5-bit index, giving 1.25 bits/weight with SIMD-friendly 4-way
//! alignment (vs 2-bit padding waste or 1.67-bit 3-way irregularity).
//!
//! **Arenas** (Annealing Residual Synapse): during QAT the forward is
//! Y = X·Q(W) + λ_t·X·W with λ_t annealed to zero, injecting heterogeneous
//! gradients that prevent representational collapse. The annealing schedule
//! lives here; the training loop is in qat/trainer.rs.

#[derive(Clone, Debug, Default)]
pub struct Sherry;

/// One quantized block: which lane is zero + the three signs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SherryBlock {
    /// 0..=3: index of the zeroed lane
    pub zero_lane: u8,
    /// sign bits of the three surviving lanes in lane order (1 = +1)
    pub signs: u8,
}

impl SherryBlock {
    /// 5-bit code: zero_lane * 8 + signs (0..=31)
    pub fn code(&self) -> u8 {
        self.zero_lane * 8 + (self.signs & 0x7)
    }

    pub fn from_code(code: u8) -> Self {
        SherryBlock { zero_lane: (code >> 3) & 0x3, signs: code & 0x7 }
    }

    /// Expand to the 4 ternary values in {-1, 0, +1}.
    pub fn expand(&self) -> [f32; 4] {
        let mut out = [0.0f32; 4];
        let mut s = 0;
        for lane in 0..4 {
            if lane == self.zero_lane as usize {
                continue;
            }
            out[lane] = if (self.signs >> s) & 1 == 1 { 1.0 } else { -1.0 };
            s += 1;
        }
        out
    }
}

impl Sherry {
    /// Quantize one block of 4: zero the min-|w| lane, sign the rest.
    pub fn quantize_block(w: &[f32; 4]) -> SherryBlock {
        let mut zero_lane = 0usize;
        for lane in 1..4 {
            if w[lane].abs() < w[zero_lane].abs() {
                zero_lane = lane;
            }
        }
        let mut signs = 0u8;
        let mut s = 0;
        for lane in 0..4 {
            if lane == zero_lane {
                continue;
            }
            if w[lane] >= 0.0 {
                signs |= 1 << s;
            }
            s += 1;
        }
        SherryBlock { zero_lane: zero_lane as u8, signs }
    }

    /// Quantize a row-major [n, k] matrix (k % 4 == 0). Returns per-row
    /// alpha (mean |w| over non-zeroed lanes) + the 5-bit block codes.
    pub fn quantize_codes(w: &[f32], n: usize, k: usize) -> (Vec<u8>, Vec<f32>) {
        assert!(k % 4 == 0, "k must be divisible by 4");
        assert_eq!(w.len(), n * k);
        let mut codes = Vec::with_capacity(n * k / 4);
        let mut alphas = Vec::with_capacity(n);
        for row in 0..n {
            let mut kept_sum = 0.0f32;
            let mut kept_n = 0usize;
            for b in (0..k).step_by(4) {
                let blk = [
                    w[row * k + b],
                    w[row * k + b + 1],
                    w[row * k + b + 2],
                    w[row * k + b + 3],
                ];
                let q = Self::quantize_block(&blk);
                for lane in 0..4 {
                    if lane != q.zero_lane as usize {
                        kept_sum += blk[lane].abs();
                        kept_n += 1;
                    }
                }
                codes.push(q.code());
            }
            let alpha = if kept_n == 0 { 1.0 } else { kept_sum / kept_n as f32 };
            alphas.push(alpha);
        }
        (codes, alphas)
    }

    pub fn dequantize_codes(codes: &[u8], alphas: &[f32], n: usize, k: usize) -> Vec<f32> {
        let blocks_per_row = k / 4;
        let mut w = vec![0.0f32; n * k];
        for row in 0..n {
            let a = alphas[row];
            for b in 0..blocks_per_row {
                let vals = SherryBlock::from_code(codes[row * blocks_per_row + b]).expand();
                for lane in 0..4 {
                    w[row * k + b * 4 + lane] = vals[lane] * a;
                }
            }
        }
        w
    }

    /// QDQ convenience used by the QAT trainer's fake-quant forward.
    pub fn qdq(w: &mut [f32], n: usize, k: usize) {
        let (codes, alphas) = Self::quantize_codes(w, n, k);
        let deq = Self::dequantize_codes(&codes, &alphas, n, k);
        w.copy_from_slice(&deq);
    }
}

/// Pipeline-pass adapter: Sherry's 3:4 structured ternary as a generic
/// weight quantizer (the registry's `sherry` pass; requires every weight
/// dimension divisible by the 4-lane block, checked in the pass's
/// `prepare`).
impl super::WeightQuantizer for Sherry {
    fn name(&self) -> &'static str {
        "sherry"
    }

    fn bits(&self) -> f64 {
        1.25
    }

    fn qdq(&self, w: &mut [f32], n: usize, k: usize) {
        Sherry::qdq(w, n, k);
    }
}

/// Arenas annealing schedule: λ_t from λ_0 down to 0 by end of training
/// (cosine decay — smooth, reaches exactly zero).
#[derive(Clone, Debug)]
pub struct ArenasSchedule {
    pub lambda0: f32,
    pub total_steps: usize,
}

impl ArenasSchedule {
    pub fn new(lambda0: f32, total_steps: usize) -> Self {
        ArenasSchedule { lambda0, total_steps }
    }

    pub fn lambda(&self, step: usize) -> f32 {
        if step >= self.total_steps {
            return 0.0;
        }
        let t = step as f32 / self.total_steps as f32;
        self.lambda0 * 0.5 * (1.0 + (std::f32::consts::PI * t).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{testing, Rng};

    #[test]
    fn block_code_roundtrip_all_32() {
        for code in 0..32u8 {
            let b = SherryBlock::from_code(code);
            assert_eq!(b.code(), code);
            let vals = b.expand();
            let zeros = vals.iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, 1, "exactly one zero per block");
            assert!(vals.iter().all(|&v| v == 0.0 || v.abs() == 1.0));
        }
    }

    #[test]
    fn quantize_zeroes_smallest_lane() {
        let q = Sherry::quantize_block(&[0.9, -0.05, -1.2, 0.4]);
        assert_eq!(q.zero_lane, 1);
        let vals = q.expand();
        assert_eq!(vals[0], 1.0);
        assert_eq!(vals[1], 0.0);
        assert_eq!(vals[2], -1.0);
        assert_eq!(vals[3], 1.0);
    }

    #[test]
    fn three_quarters_density_exact() {
        testing::check(8, |rng| {
            let (n, k) = (8, 64);
            let w = rng.normal_vec(n * k, 1.0);
            let (codes, alphas) = Sherry::quantize_codes(&w, n, k);
            let deq = Sherry::dequantize_codes(&codes, &alphas, n, k);
            let nz = deq.iter().filter(|&&v| v != 0.0).count();
            assert_eq!(nz, n * k * 3 / 4, "3:4 structured sparsity");
        });
    }

    #[test]
    fn qdq_error_bounded_vs_dense_ternary() {
        // Sherry drops the min-|w| lane per block: its extra error relative
        // to plain sign*alpha is bounded by the dropped mass.
        let mut rng = Rng::new(0);
        let orig = rng.normal_vec(16 * 64, 1.0);
        let mut w = orig.clone();
        Sherry::qdq(&mut w, 16, 64);
        let mse = crate::util::stats::mse(&w, &orig);
        assert!(mse < 1.0, "sherry mse {mse}");
        // correlation with the original stays positive and strong-ish
        let corr = crate::util::stats::pearson(&w, &orig);
        assert!(corr > 0.6, "corr {corr}");
    }

    #[test]
    fn arenas_anneals_to_zero() {
        let s = ArenasSchedule::new(0.3, 100);
        assert!((s.lambda(0) - 0.3).abs() < 1e-6);
        assert!(s.lambda(50) < 0.3);
        assert!(s.lambda(50) > 0.0);
        assert_eq!(s.lambda(100), 0.0);
        assert_eq!(s.lambda(500), 0.0);
        // monotone non-increasing
        let mut prev = f32::INFINITY;
        for t in 0..=100 {
            let l = s.lambda(t);
            assert!(l <= prev + 1e-6);
            prev = l;
        }
    }
}
