//! LeptoQuant — Dynamic Outlier Isolation Scale search (paper §2.3.2).
//!
//! Observation: activation/weight distributions are leptokurtic (Laplacian
//! peak + outliers). Traditional FP8 absmax scaling spends the format's
//! dense-near-zero precision on the outlier range and smooths the densely
//! populated region into coarse bins. LeptoQuant searches a small grid of
//! outlier-isolation fractions α ∈ [0, 0.001]: the (1-α)-quantile replaces
//! absmax as the scale denominator D (eq. 5), compressing the dense mass
//! into the high-precision region (values beyond D saturate). The α that
//! minimizes block output MSE (eq. 7) wins; α = 0 recovers traditional FP8.

use crate::quant::fp8::{fp8_e4m3_qdq, Fp8Format};
use crate::tensor::{ops::matmul_transb, Tensor};

#[derive(Clone, Debug)]
pub struct LeptoQuant {
    /// α search grid; paper: fast grid search over [0, 0.001]
    pub alpha_grid: Vec<f64>,
    pub format: Fp8Format,
    /// also QDQ the weights (per-tensor absmax) when simulating the block
    pub quantize_weights: bool,
}

impl Default for LeptoQuant {
    fn default() -> Self {
        LeptoQuant {
            alpha_grid: vec![0.0, 0.0001, 0.00025, 0.0005, 0.001],
            format: Fp8Format::E4M3,
            quantize_weights: true,
        }
    }
}

#[derive(Clone, Debug)]
pub struct LeptoResult {
    pub best_alpha: f64,
    /// chosen activation scale (denominator D / fp8_max)
    pub act_scale: f32,
    pub mse_traditional: f32,
    pub mse_best: f32,
}

impl LeptoQuant {
    /// Upper-quantile |x| — Outlier(X, α) of eq. 5.
    fn outlier(xs: &[f32], alpha: f64) -> f32 {
        let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| a.total_cmp(b));
        if alpha <= 0.0 {
            return *mags.last().unwrap_or(&1.0);
        }
        let idx = ((1.0 - alpha) * (mags.len() - 1) as f64).round() as usize;
        mags[idx.min(mags.len() - 1)]
    }

    /// QDQ activations with scale D/fmax (outliers saturate).
    fn qdq_acts(&self, x: &Tensor, d: f32) -> Tensor {
        let scale = (d / self.format.max()).max(1e-12);
        let mut out = x.clone();
        for v in out.data.iter_mut() {
            *v = self.format.qdq(*v / scale) * scale;
        }
        out
    }

    /// Search the α grid for one linear block: activations x [m, k],
    /// weights w [n, k]. Returns the winning α + diagnostics.
    pub fn search(&self, x: &Tensor, w: &Tensor) -> LeptoResult {
        assert_eq!(x.cols(), w.cols());
        // weight QDQ fixed across the search (we prioritize activations,
        // like the paper: "quantizing activations is generally harder")
        let wq = if self.quantize_weights {
            let mut wq = w.clone();
            let absmax = wq.data.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-12);
            let ws = absmax / self.format.max();
            for v in wq.data.iter_mut() {
                *v = fp8_e4m3_qdq(*v / ws) * ws;
            }
            wq
        } else {
            w.clone()
        };
        let y_ref = matmul_transb(x, w);

        let mut best_alpha = 0.0f64;
        let mut best_scale = 0.0f32;
        let mut best_mse = f32::INFINITY;
        let mut trad_mse = f32::INFINITY;
        for &alpha in &self.alpha_grid {
            let d = Self::outlier(&x.data, alpha);
            let xq = self.qdq_acts(x, d);
            let y = matmul_transb(&xq, &wq);
            let mse = crate::util::stats::mse(&y.data, &y_ref.data);
            if alpha == 0.0 {
                trad_mse = mse;
            }
            if mse < best_mse {
                best_mse = mse;
                best_alpha = alpha;
                best_scale = d / self.format.max();
            }
        }
        LeptoResult {
            best_alpha,
            act_scale: best_scale,
            mse_traditional: trad_mse,
            mse_best: best_mse,
        }
    }

    /// Apply the chosen scale to fresh activations (deployment path).
    pub fn apply(&self, x: &mut [f32], act_scale: f32) {
        for v in x.iter_mut() {
            *v = self.format.qdq(*v / act_scale) * act_scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Leptokurtic activations in the regime where outlier isolation pays:
    /// a dense Laplacian body whose absmax-scaled fp8 image lands in the
    /// flush-to-zero band, plus rare "massive activation" elements confined
    /// to a sink channel whose weight column is ~zero (the attention-sink
    /// phenomenon the paper's Figure 7 analysis describes: the densely
    /// populated near-zero mass is what carries signal; traditional absmax
    /// scaling smooths it away).
    fn lepto_acts(m: usize, k: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let mut x = Tensor::zeros(&[m, k]);
        for v in x.data.iter_mut() {
            // Laplace(0, 1e-5) via inverse CDF — ~1e-6 of the outlier scale
            let u = rng.f64() - 0.5;
            *v = (-1e-5 * (1.0 - 2.0 * u.abs()).ln() * u.signum()) as f32;
        }
        // rare massive activations (<0.1% of elements), channel 0 only
        for r in 0..m {
            if rng.bool(0.05) {
                x.row_mut(r)[0] = 6.0 * if rng.bool(0.5) { 1.0 } else { -1.0 };
            }
        }
        x
    }

    #[test]
    fn lepto_beats_traditional_on_leptokurtic_data() {
        let mut rng = Rng::new(0);
        let mut w = Tensor::randn(&[32, 128], 0.3, &mut rng);
        for r in 0..32 {
            w.row_mut(r)[0] = 0.0; // sink channel carries no weight
        }
        let x = lepto_acts(64, 128, 1);
        let lq = LeptoQuant { quantize_weights: false, ..Default::default() };
        let res = lq.search(&x, &w);
        assert!(
            res.mse_best < res.mse_traditional * 0.5,
            "lepto {} vs traditional {}",
            res.mse_best,
            res.mse_traditional
        );
        assert!(res.best_alpha > 0.0);
    }

    #[test]
    fn alpha_zero_recovers_traditional() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&[16, 64], 0.3, &mut rng);
        let x = Tensor::randn(&[32, 64], 1.0, &mut rng);
        let lq = LeptoQuant { alpha_grid: vec![0.0], ..Default::default() };
        let res = lq.search(&x, &w);
        assert_eq!(res.best_alpha, 0.0);
        assert_eq!(res.mse_best, res.mse_traditional);
    }

    #[test]
    fn gaussian_data_prefers_small_alpha() {
        // without heavy outliers the optimum stays at/near traditional
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[16, 64], 0.3, &mut rng);
        let x = Tensor::randn(&[64, 64], 1.0, &mut rng);
        let res = LeptoQuant::default().search(&x, &w);
        // best can still be a tiny alpha, but must not be much better than
        // traditional — there are no outliers to isolate
        assert!(res.mse_best >= res.mse_traditional * 0.5);
    }

    #[test]
    fn outlier_quantile_monotone() {
        let xs: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let a = LeptoQuant::outlier(&xs, 0.0);
        let b = LeptoQuant::outlier(&xs, 0.001);
        let c = LeptoQuant::outlier(&xs, 0.01);
        assert!(a >= b && b >= c);
    }

    #[test]
    fn apply_saturates_outliers() {
        let lq = LeptoQuant::default();
        let mut xs = vec![0.01f32, -0.02, 5.0];
        lq.apply(&mut xs, 0.05 / 448.0); // scale chosen for the dense body
        assert!((xs[0] - 0.01).abs() < 0.002);
        assert!(xs[2] < 0.1, "outlier saturates to D");
    }
}
