//! The metadata-driven pruning interface (paper Fig. 12): strategies see a
//! `PruneContext` and emit a keep-mask (`Pruner`) or a reduced token list
//! (`Reducer`, for merge-capable audio methods). The framework handles the
//! downstream slicing.

/// Runtime context handed to a pruning strategy — the "metadata" the
/// framework captures during the forward pass (features, attention-derived
/// importance, budget).
#[derive(Clone, Debug)]
pub struct PruneContext<'a> {
    /// token features [n][dim]
    pub features: &'a [Vec<f32>],
    /// per-token importance (attention metadata); empty if unavailable
    pub importance: &'a [f32],
    /// number of tokens to retain
    pub retain: usize,
}

impl<'a> PruneContext<'a> {
    pub fn n(&self) -> usize {
        self.features.len()
    }

    /// Pairwise cosine similarity matrix (computed lazily by strategies
    /// that need it).
    pub fn similarity(&self) -> Vec<Vec<f32>> {
        let n = self.n();
        let mut sim = vec![vec![0.0f32; n]; n];
        for i in 0..n {
            sim[i][i] = 1.0;
            for j in 0..i {
                let s = crate::util::stats::cosine(&self.features[i], &self.features[j]);
                sim[i][j] = s;
                sim[j][i] = s;
            }
        }
        sim
    }
}

/// A pruning strategy: boolean keep-mask of length n with exactly
/// `ctx.retain` true entries (the framework enforces this in `apply`).
pub trait Pruner {
    fn name(&self) -> &'static str;
    fn prune(&self, ctx: &PruneContext) -> Vec<bool>;

    /// Framework-side application: run the strategy, repair budget
    /// violations (top-up by importance, trim by reverse importance), and
    /// return kept indices in original order.
    fn apply(&self, ctx: &PruneContext) -> Vec<usize> {
        let mut mask = self.prune(ctx);
        assert_eq!(mask.len(), ctx.n());
        let kept = mask.iter().filter(|&&b| b).count();
        if kept > ctx.retain {
            // trim lowest-importance kept tokens
            let mut idx: Vec<usize> = (0..ctx.n()).filter(|&i| mask[i]).collect();
            idx.sort_by(|&a, &b| {
                score(ctx, a).total_cmp(&score(ctx, b))
            });
            for &i in idx.iter().take(kept - ctx.retain) {
                mask[i] = false;
            }
        } else if kept < ctx.retain {
            let mut idx: Vec<usize> = (0..ctx.n()).filter(|&i| !mask[i]).collect();
            idx.sort_by(|&a, &b| score(ctx, b).total_cmp(&score(ctx, a)));
            for &i in idx.iter().take(ctx.retain - kept) {
                mask[i] = true;
            }
        }
        (0..ctx.n()).filter(|&i| mask[i]).collect()
    }
}

fn score(ctx: &PruneContext, i: usize) -> f32 {
    ctx.importance.get(i).copied().unwrap_or(0.0)
}

/// A reduced token: a (possibly merged) feature + the original position of
/// its first constituent (for order-preserving downstream decoding).
#[derive(Clone, Debug)]
pub struct ReducedToken {
    pub feature: Vec<f32>,
    pub first_pos: usize,
    /// number of original tokens merged into this one
    pub span: usize,
}

/// A merge-capable reduction strategy (audio): tokens in, reduced tokens
/// out, ordered by first_pos.
pub trait Reducer {
    fn name(&self) -> &'static str;
    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken>;
}

/// Adapter: any Pruner is a Reducer that keeps raw features.
pub struct PrunerAsReducer<P: Pruner>(pub P);

impl<P: Pruner> Reducer for PrunerAsReducer<P> {
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken> {
        self.0
            .apply(ctx)
            .into_iter()
            .map(|i| ReducedToken {
                feature: ctx.features[i].clone(),
                first_pos: i,
                span: 1,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct KeepFirstHalf;

    impl Pruner for KeepFirstHalf {
        fn name(&self) -> &'static str {
            "first-half"
        }

        fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
            (0..ctx.n()).map(|i| i < ctx.n() / 2).collect()
        }
    }

    fn ctx_data(n: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let feats: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32, 1.0]).collect();
        let imp: Vec<f32> = (0..n).map(|i| i as f32).collect();
        (feats, imp)
    }

    #[test]
    fn apply_repairs_overfull_mask() {
        let (feats, imp) = ctx_data(10);
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 3 };
        let kept = KeepFirstHalf.apply(&ctx);
        assert_eq!(kept.len(), 3);
        // trimmed the lowest-importance (smallest index) kept tokens
        assert_eq!(kept, vec![2, 3, 4]);
    }

    #[test]
    fn apply_tops_up_underfull_mask() {
        let (feats, imp) = ctx_data(10);
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 8 };
        let kept = KeepFirstHalf.apply(&ctx);
        assert_eq!(kept.len(), 8);
        // topped up with the highest-importance dropped tokens (9, 8, 7)
        assert!(kept.contains(&9) && kept.contains(&8) && kept.contains(&7));
    }

    #[test]
    fn pruner_as_reducer_preserves_features() {
        let (feats, imp) = ctx_data(6);
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 2 };
        let red = PrunerAsReducer(KeepFirstHalf).reduce(&ctx);
        assert_eq!(red.len(), 2);
        assert!(red.iter().all(|r| r.span == 1));
        assert_eq!(red[0].feature, feats[red[0].first_pos]);
    }

    #[test]
    fn similarity_symmetric_unit_diag() {
        let feats = vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]];
        let imp = vec![0.0; 3];
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 2 };
        let s = ctx.similarity();
        assert_eq!(s[0][0], 1.0);
        assert_eq!(s[0][1], s[1][0]);
        assert!(s[0][1].abs() < 1e-6);
    }
}
