//! Greedy MAP inference for DPP-style diverse selection — the kernel-based
//! pruning core behind Samp's second stage (eq. 10) and CDPruner.
//!
//! Greedy MAP on a PSD kernel L: repeatedly add the item maximizing the
//! marginal gain of log det(L_S). We use the standard Cholesky-style
//! incremental update (Chen et al., fast greedy MAP).

/// Greedy MAP selection of k items from kernel L ([n][n], PSD-ish).
pub fn dpp_map_select(l: &[Vec<f32>], k: usize) -> Vec<usize> {
    let n = l.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    // d[i] = marginal gain (initially the diagonal); c[i] = row of the
    // incremental Cholesky factor restricted to selected items
    let mut d: Vec<f32> = (0..n).map(|i| l[i][i].max(1e-12)).collect();
    let mut c: Vec<Vec<f32>> = vec![Vec::with_capacity(k); n];
    let mut selected = Vec::with_capacity(k);
    let mut taken = vec![false; n];

    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_gain = f32::NEG_INFINITY;
        for i in 0..n {
            if !taken[i] && d[i] > best_gain {
                best_gain = d[i];
                best = i;
            }
        }
        if best == usize::MAX || best_gain <= 1e-12 {
            // kernel rank exhausted (rank(L) <= feature dim): fill the
            // remaining budget by original quality so callers always get k
            let mut rest: Vec<usize> = (0..n).filter(|i| !taken[*i]).collect();
            rest.sort_by(|&a, &b| l[b][b].total_cmp(&l[a][a]));
            for i in rest.into_iter().take(k - selected.len()) {
                selected.push(i);
                taken[i] = true;
            }
            break;
        }
        selected.push(best);
        taken[best] = true;
        let dj = d[best].sqrt();
        let cj = c[best].clone();
        for i in 0..n {
            if taken[i] {
                continue;
            }
            let dot: f32 = cj.iter().zip(&c[i]).map(|(a, b)| a * b).sum();
            let e = (l[best][i] - dot) / dj;
            c[i].push(e);
            d[i] = (d[i] - e * e).max(0.0);
        }
    }
    selected.sort_unstable();
    selected
}

/// Conditional kernel of Samp (eq. 10): L' = diag(a) · L · diag(a), where
/// `a` are importance scores — biases the DPP toward salient items while
/// keeping the diversity structure.
pub fn conditional_kernel(l: &[Vec<f32>], a: &[f32]) -> Vec<Vec<f32>> {
    let n = l.len();
    assert_eq!(a.len(), n);
    let mut out = vec![vec![0.0f32; n]; n];
    for i in 0..n {
        for j in 0..n {
            out[i][j] = a[i] * l[i][j] * a[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rbf_kernel(feats: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let n = feats.len();
        let mut l = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                let d2: f32 = feats[i]
                    .iter()
                    .zip(&feats[j])
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                l[i][j] = (-d2).exp();
            }
        }
        l
    }

    #[test]
    fn prefers_diverse_points() {
        // two tight clusters; k=2 should take one from each
        let feats = vec![
            vec![0.0, 0.0],
            vec![0.05, 0.0],
            vec![0.0, 0.05],
            vec![5.0, 5.0],
            vec![5.05, 5.0],
        ];
        let sel = dpp_map_select(&rbf_kernel(&feats), 2);
        assert_eq!(sel.len(), 2);
        let cluster = |i: usize| if feats[i][0] > 2.0 { 1 } else { 0 };
        assert_ne!(cluster(sel[0]), cluster(sel[1]), "{sel:?}");
    }

    #[test]
    fn conditional_kernel_biases_to_importance() {
        let feats = vec![vec![0.0, 0.0], vec![0.1, 0.0], vec![5.0, 5.0]];
        let l = rbf_kernel(&feats);
        // item 1 hugely important
        let a = vec![0.1, 10.0, 0.1];
        let lc = conditional_kernel(&l, &a);
        let sel = dpp_map_select(&lc, 1);
        assert_eq!(sel, vec![1]);
    }

    #[test]
    fn selects_requested_count() {
        let feats: Vec<Vec<f32>> =
            (0..12).map(|i| vec![(i as f32).sin() * 3.0, (i as f32).cos() * 3.0]).collect();
        let sel = dpp_map_select(&rbf_kernel(&feats), 6);
        assert_eq!(sel.len(), 6);
        let mut d = sel.clone();
        d.dedup();
        assert_eq!(d.len(), 6);
    }

    #[test]
    fn k_zero_empty() {
        assert!(dpp_map_select(&[vec![1.0]], 0).is_empty());
    }
}
