//! Token pruning & merging for multimodal models — pillar 4 (§4.2).
//!
//! The framework mirrors the paper's decoupling (Fig. 12): a pruning
//! strategy is a standalone function from runtime context (features,
//! importance metadata, retain budget) to a boolean keep-mask; downstream
//! bookkeeping (slicing, metadata sync) is the framework's job. Visual
//! methods rank/select; audio methods may also *merge* (Samp, A-ToMe).

pub mod audio;
pub mod dpp;
pub mod framework;
pub mod mmr;
pub mod visual;

pub use framework::{PruneContext, Pruner, ReducedToken, Reducer};
pub use mmr::mmr_select;
