//! Visual token pruning strategies — IDPruner (§4.2.2) plus the eight
//! baselines of Table 12. Attention-map-based baselines use the importance
//! metadata the framework captures; learnable baselines (VisionSelector)
//! are implemented as their published selection rule's strongest
//! training-free proxy (documented per struct).

use super::framework::{PruneContext, Pruner};
use super::mmr::mmr_select;

fn mask_from(indices: &[usize], n: usize) -> Vec<bool> {
    let mut m = vec![false; n];
    for &i in indices {
        m[i] = true;
    }
    m
}

fn topk_by(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
    idx.truncate(k);
    idx
}

// --------------------------------------------------------------------------
// IDPruner — the paper's contribution
// --------------------------------------------------------------------------

/// IDPruner: MMR re-ranking over (normalized saliency, pairwise semantic
/// similarity) — importance *and* diversity, no attention maps required
/// (falls back to feature norms when importance metadata is absent).
pub struct IdPruner {
    pub lambda: f32,
}

impl Default for IdPruner {
    fn default() -> Self {
        IdPruner { lambda: 0.6 }
    }
}

impl Pruner for IdPruner {
    fn name(&self) -> &'static str {
        "IDPruner"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let imp: Vec<f32> = if ctx.importance.is_empty() {
            ctx.features
                .iter()
                .map(|f| f.iter().map(|x| x * x).sum::<f32>().sqrt())
                .collect()
        } else {
            ctx.importance.to_vec()
        };
        let sim = ctx.similarity();
        mask_from(&mmr_select(&imp, &sim, ctx.retain, self.lambda), ctx.n())
    }
}

// --------------------------------------------------------------------------
// baselines
// --------------------------------------------------------------------------

/// FastV: rank purely by attention importance (single-metric baseline).
pub struct FastV;

impl Pruner for FastV {
    fn name(&self) -> &'static str {
        "FastV"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        mask_from(&topk_by(ctx.importance, ctx.retain), ctx.n())
    }
}

/// DivPrune: pure diversity — greedy farthest-point (max-min distance)
/// selection, ignoring importance.
pub struct DivPrune;

impl Pruner for DivPrune {
    fn name(&self) -> &'static str {
        "DivPrune"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let sim = ctx.similarity();
        let mut selected = vec![0usize];
        let mut min_sim: Vec<f32> = sim.iter().map(|row| row[0]).collect();
        while selected.len() < ctx.retain.min(n) {
            let mut best = usize::MAX;
            let mut best_val = f32::INFINITY;
            for i in 0..n {
                if !selected.contains(&i) && min_sim[i] < best_val {
                    best_val = min_sim[i];
                    best = i;
                }
            }
            selected.push(best);
            for i in 0..n {
                min_sim[i] = min_sim[i].max(sim[i][best]);
            }
        }
        mask_from(&selected, n)
    }
}

/// VisionZip: dominant tokens by importance (most of the budget) + a
/// stride-sampled "contextual" remainder standing in for merged tokens.
pub struct VisionZip;

impl Pruner for VisionZip {
    fn name(&self) -> &'static str {
        "VisionZip"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let dominant = (ctx.retain as f32 * 0.75).round() as usize;
        let mut keep = topk_by(ctx.importance, dominant);
        let rest = ctx.retain - keep.len().min(ctx.retain);
        if rest > 0 {
            let remaining: Vec<usize> = (0..n).filter(|i| !keep.contains(i)).collect();
            let stride = (remaining.len() / rest.max(1)).max(1);
            keep.extend(remaining.into_iter().step_by(stride).take(rest));
        }
        mask_from(&keep, n)
    }
}

/// DART: duplication-aware — drop the token most similar to an
/// already-kept pivot set, iteratively (duplication matters more than
/// importance).
pub struct Dart;

impl Pruner for Dart {
    fn name(&self) -> &'static str {
        "DART"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let sim = ctx.similarity();
        // pivots: a small stride sample
        let pivots: Vec<usize> = (0..n).step_by((n / 8).max(1)).collect();
        // redundancy = max similarity to any pivot (excluding self)
        let mut red: Vec<f32> = (0..n)
            .map(|i| {
                pivots
                    .iter()
                    .filter(|&&p| p != i)
                    .map(|&p| sim[i][p])
                    .fold(f32::NEG_INFINITY, f32::max)
            })
            .collect();
        for (i, r) in red.iter_mut().enumerate() {
            if pivots.contains(&i) {
                *r = f32::NEG_INFINITY; // pivots always kept first
            }
        }
        // keep the LEAST redundant tokens
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| red[a].total_cmp(&red[b]));
        idx.truncate(ctx.retain);
        mask_from(&idx, n)
    }
}

/// VisPruner: importance for half the budget, farthest-point diversity for
/// the rest (visual-cue hybrid).
pub struct VisPruner;

impl Pruner for VisPruner {
    fn name(&self) -> &'static str {
        "VisPruner"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let half = ctx.retain / 2;
        let mut keep = topk_by(ctx.importance, half);
        let sim = ctx.similarity();
        let mut max_sim = vec![f32::NEG_INFINITY; n];
        for i in 0..n {
            for &s in &keep {
                max_sim[i] = max_sim[i].max(sim[i][s]);
            }
        }
        while keep.len() < ctx.retain.min(n) {
            let mut best = usize::MAX;
            let mut best_val = f32::INFINITY;
            for i in 0..n {
                if !keep.contains(&i) && max_sim[i] < best_val {
                    best_val = max_sim[i];
                    best = i;
                }
            }
            keep.push(best);
            for i in 0..n {
                max_sim[i] = max_sim[i].max(sim[i][best]);
            }
        }
        mask_from(&keep, n)
    }
}

/// SCOPE: saliency-coverage greedy — marginal gain = importance + coverage
/// improvement over the feature set.
pub struct Scope;

impl Pruner for Scope {
    fn name(&self) -> &'static str {
        "SCOPE"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let sim = ctx.similarity();
        let lo = ctx.importance.iter().cloned().fold(f32::INFINITY, f32::min);
        let hi = ctx.importance.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let range = (hi - lo).max(1e-9);
        let imp: Vec<f32> = ctx.importance.iter().map(|&v| (v - lo) / range).collect();
        let mut cover = vec![0.0f32; n]; // current max sim to selected
        let mut keep: Vec<usize> = Vec::new();
        while keep.len() < ctx.retain.min(n) {
            let mut best = usize::MAX;
            let mut best_gain = f32::NEG_INFINITY;
            for i in 0..n {
                if keep.contains(&i) {
                    continue;
                }
                // coverage gain: how much adding i raises everyone's cover
                let gain: f32 = (0..n)
                    .step_by(2)
                    .map(|j| (sim[j][i] - cover[j]).max(0.0))
                    .sum::<f32>()
                    / (n as f32 / 2.0);
                let score = 0.5 * imp[i] + 0.5 * gain;
                if score > best_gain {
                    best_gain = score;
                    best = i;
                }
            }
            keep.push(best);
            for j in 0..n {
                cover[j] = cover[j].max(sim[j][best]);
            }
        }
        mask_from(&keep, n)
    }
}

/// VisionSelector proxy: the published method learns an end-to-end scorer;
/// training-free proxy = importance blended with feature-norm saliency,
/// with a soft redundancy penalty.
pub struct VisionSelector;

impl Pruner for VisionSelector {
    fn name(&self) -> &'static str {
        "VisionSelector"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let imp: Vec<f32> = ctx
            .features
            .iter()
            .zip(ctx.importance)
            .map(|(f, &a)| {
                let norm = f.iter().map(|x| x * x).sum::<f32>().sqrt();
                0.6 * a + 0.4 * norm
            })
            .collect();
        let sim = ctx.similarity();
        mask_from(&mmr_select(&imp, &sim, ctx.retain, 0.75), ctx.n())
    }
}

/// HiPrune: hierarchical — anchor tokens by importance, then their most
/// similar neighbours (keeps local context around anchors).
pub struct HiPrune;

impl Pruner for HiPrune {
    fn name(&self) -> &'static str {
        "HiPrune"
    }

    fn prune(&self, ctx: &PruneContext) -> Vec<bool> {
        let n = ctx.n();
        let anchors = topk_by(ctx.importance, (ctx.retain / 2).max(1));
        let sim = ctx.similarity();
        let mut keep = anchors.clone();
        let mut i = 0;
        while keep.len() < ctx.retain.min(n) {
            let a = anchors[i % anchors.len()];
            // nearest unkept neighbour of this anchor
            let next = (0..n)
                .filter(|j| !keep.contains(j))
                .max_by(|&x, &y| sim[a][x].total_cmp(&sim[a][y]));
            match next {
                Some(j) => keep.push(j),
                None => break,
            }
            i += 1;
        }
        mask_from(&keep, n)
    }
}

/// Every Table 12 strategy, boxed for sweep benches.
pub fn all_visual_pruners() -> Vec<Box<dyn Pruner>> {
    vec![
        Box::new(FastV),
        Box::new(VisionZip),
        Box::new(HiPrune),
        Box::new(VisionSelector),
        Box::new(DivPrune),
        Box::new(Dart),
        Box::new(VisPruner),
        Box::new(Scope),
        Box::new(IdPruner::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::VisionSceneGen;

    fn scene_ctx() -> (Vec<Vec<f32>>, Vec<f32>) {
        let gen = VisionSceneGen::new(96, 16, 4, 0);
        let s = gen.scene(0);
        (s.features, s.importance)
    }

    #[test]
    fn every_pruner_respects_budget() {
        let (feats, imp) = scene_ctx();
        for retain in [8, 24, 48] {
            let ctx = PruneContext { features: &feats, importance: &imp, retain };
            for p in all_visual_pruners() {
                let kept = p.apply(&ctx);
                assert_eq!(kept.len(), retain, "{} at {retain}", p.name());
                assert!(kept.windows(2).all(|w| w[0] < w[1]), "sorted order");
            }
        }
    }

    #[test]
    fn fastv_keeps_most_important() {
        let feats = vec![vec![1.0]; 5];
        let imp = vec![0.1, 0.9, 0.3, 0.8, 0.2];
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 2 };
        let kept = FastV.apply(&ctx);
        assert_eq!(kept, vec![1, 3]);
    }

    #[test]
    fn idpruner_beats_fastv_on_redundant_salient_set() {
        // two identical high-importance tokens + one distinct medium one:
        // FastV keeps the duplicates, IDPruner keeps one + the distinct
        let feats = vec![
            vec![1.0, 0.0, 0.0],
            vec![1.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 0.2],
        ];
        let imp = vec![1.0, 0.98, 0.6, 0.1];
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 2 };
        let fv = FastV.apply(&ctx);
        let id = IdPruner::default().apply(&ctx);
        assert_eq!(fv, vec![0, 1], "fastv falls for duplicates");
        assert!(id.contains(&2), "idpruner diversifies: {id:?}");
    }

    #[test]
    fn divprune_spreads_over_clusters() {
        // 3 clusters, retain 3 -> one from each
        let feats = vec![
            vec![1.0, 0.0],
            vec![0.99, 0.01],
            vec![0.0, 1.0],
            vec![0.01, 0.99],
            vec![-1.0, 0.0],
            vec![-0.99, -0.01],
        ];
        let imp = vec![0.5; 6];
        let ctx = PruneContext { features: &feats, importance: &imp, retain: 3 };
        let kept = DivPrune.apply(&ctx);
        let clusters: std::collections::HashSet<usize> =
            kept.iter().map(|&i| i / 2).collect();
        assert_eq!(clusters.len(), 3, "{kept:?}");
    }
}
