//! Maximal Marginal Relevance — IDPruner's selection core (§4.2.2):
//! iteratively pick the token maximizing
//!     λ · importance_norm(i) − (1 − λ) · max_{j ∈ S} sim(i, j)
//! balancing saliency against redundancy with the already-selected set.

/// Greedy MMR selection of `k` indices.
/// `importance` is normalized to [0, 1] internally; `sim` is [n][n].
pub fn mmr_select(importance: &[f32], sim: &[Vec<f32>], k: usize, lambda: f32) -> Vec<usize> {
    let n = importance.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let lo = importance.iter().cloned().fold(f32::INFINITY, f32::min);
    let hi = importance.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let range = (hi - lo).max(1e-9);
    let norm: Vec<f32> = importance.iter().map(|&v| (v - lo) / range).collect();

    let mut selected = Vec::with_capacity(k);
    let mut max_sim = vec![0.0f32; n]; // max similarity to selected set
    let mut taken = vec![false; n];

    // seed with the most important token
    let first = (0..n).max_by(|&a, &b| norm[a].total_cmp(&norm[b])).unwrap();
    selected.push(first);
    taken[first] = true;
    for i in 0..n {
        max_sim[i] = sim[i][first];
    }

    while selected.len() < k {
        let mut best = usize::MAX;
        let mut best_score = f32::NEG_INFINITY;
        for i in 0..n {
            if taken[i] {
                continue;
            }
            let score = lambda * norm[i] - (1.0 - lambda) * max_sim[i];
            if score > best_score {
                best_score = score;
                best = i;
            }
        }
        selected.push(best);
        taken[best] = true;
        for i in 0..n {
            max_sim[i] = max_sim[i].max(sim[i][best]);
        }
    }
    selected.sort_unstable();
    selected
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim_from(feats: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let n = feats.len();
        let mut s = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                s[i][j] = crate::util::stats::cosine(&feats[i], &feats[j]);
            }
        }
        s
    }

    #[test]
    fn lambda_one_is_topk_importance() {
        let imp = vec![0.1, 0.9, 0.5, 0.7];
        let sim = vec![vec![1.0; 4]; 4];
        let sel = mmr_select(&imp, &sim, 2, 1.0);
        assert_eq!(sel, vec![1, 3]);
    }

    #[test]
    fn low_lambda_avoids_duplicates() {
        // tokens 0,1 identical & most important; token 2 orthogonal
        let feats = vec![vec![1.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let imp = vec![1.0, 0.99, 0.2];
        let sel = mmr_select(&imp, &sim_from(&feats), 2, 0.3);
        assert!(sel.contains(&0));
        assert!(sel.contains(&2), "diversity should beat the duplicate: {sel:?}");
    }

    #[test]
    fn selects_k_distinct() {
        let imp: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let sim = vec![vec![0.0; 10]; 10];
        let sel = mmr_select(&imp, &sim, 5, 0.5);
        assert_eq!(sel.len(), 5);
        let mut d = sel.clone();
        d.dedup();
        assert_eq!(d.len(), 5);
    }

    #[test]
    fn k_zero_and_k_over_n() {
        let imp = vec![1.0, 2.0];
        let sim = vec![vec![1.0, 0.0], vec![0.0, 1.0]];
        assert!(mmr_select(&imp, &sim, 0, 0.5).is_empty());
        assert_eq!(mmr_select(&imp, &sim, 5, 0.5).len(), 2);
    }
}
