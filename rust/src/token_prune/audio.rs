//! Audio token reduction — Samp (§4.2.3) and the Table 13 baselines.
//!
//! Audio methods can *merge* (collapse runs of redundant frames into
//! weighted centroids) as well as prune, so they implement `Reducer`.

use super::dpp::{conditional_kernel, dpp_map_select};
use super::framework::{PruneContext, PrunerAsReducer, ReducedToken, Reducer};
use super::visual::{VisPruner, VisionZip};
use crate::util::stats::cosine;

fn weighted_merge(features: &[Vec<f32>], idxs: &[usize], weights: &[f32]) -> Vec<f32> {
    let dim = features[0].len();
    let mut out = vec![0.0f32; dim];
    let mut wsum = 0.0f32;
    for &i in idxs {
        let w = weights[i].max(1e-6);
        wsum += w;
        for j in 0..dim {
            out[j] += features[i][j] * w;
        }
    }
    for o in out.iter_mut() {
        *o /= wsum.max(1e-6);
    }
    out
}

/// Cluster adjacent tokens whose mean similarity to the cluster exceeds λ
/// (Samp's merging stage, eq. 8). Returns clusters as index ranges.
pub fn adjacent_clusters(features: &[Vec<f32>], lambda: f32) -> Vec<Vec<usize>> {
    let mut clusters: Vec<Vec<usize>> = Vec::new();
    for i in 0..features.len() {
        if let Some(cur) = clusters.last_mut() {
            let mean_sim: f32 = cur
                .iter()
                .map(|&t| cosine(&features[i], &features[t]))
                .sum::<f32>()
                / cur.len() as f32;
            if mean_sim >= lambda {
                cur.push(i);
                continue;
            }
        }
        clusters.push(vec![i]);
    }
    clusters
}

// --------------------------------------------------------------------------
// Samp — the paper's audio contribution
// --------------------------------------------------------------------------

/// Samp: similarity-attention synergistic merge-then-prune.
/// Stage 1 merges adjacent similar frames with attention-weighted averaging
/// (eq. 9); stage 2 prunes the merged tokens via DPP-MAP on the
/// importance-conditioned kernel (eq. 10). The similarity threshold λ
/// adaptively calibrates the merge/prune split per sample.
pub struct Samp {
    pub lambda: f32,
}

impl Default for Samp {
    fn default() -> Self {
        Samp { lambda: 0.85 }
    }
}

impl Reducer for Samp {
    fn name(&self) -> &'static str {
        "Samp"
    }

    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken> {
        // stage 1: adjacent merge
        let clusters = adjacent_clusters(ctx.features, self.lambda);
        let merged: Vec<ReducedToken> = clusters
            .iter()
            .map(|c| ReducedToken {
                feature: weighted_merge(ctx.features, c, ctx.importance),
                first_pos: c[0],
                span: c.len(),
            })
            .collect();
        if merged.len() <= ctx.retain {
            return merged;
        }
        // stage 2: diversity-driven prune via importance-conditioned DPP
        let n = merged.len();
        let mut l = vec![vec![0.0f32; n]; n];
        for i in 0..n {
            for j in 0..n {
                l[i][j] = (cosine(&merged[i].feature, &merged[j].feature) + 1.0) / 2.0;
            }
        }
        // cluster importance = mean frame attention
        let a: Vec<f32> = clusters
            .iter()
            .map(|c| {
                c.iter().map(|&t| ctx.importance[t]).sum::<f32>() / c.len() as f32 + 0.05
            })
            .collect();
        let lc = conditional_kernel(&l, &a);
        let keep = dpp_map_select(&lc, ctx.retain);
        keep.into_iter().map(|i| merged[i].clone()).collect()
    }
}

// --------------------------------------------------------------------------
// baselines
// --------------------------------------------------------------------------

/// A-ToMe: pure adjacent token merging by similarity threshold, no prune;
/// threshold is tightened until the budget is met.
pub struct AToMe;

impl Reducer for AToMe {
    fn name(&self) -> &'static str {
        "A-ToMe"
    }

    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken> {
        let mut lambda = 0.95f32;
        loop {
            let clusters = adjacent_clusters(ctx.features, lambda);
            if clusters.len() <= ctx.retain || lambda < 0.2 {
                return clusters
                    .iter()
                    .map(|c| ReducedToken {
                        feature: weighted_merge(
                            ctx.features,
                            c,
                            &vec![1.0; ctx.features.len()],
                        ),
                        first_pos: c[0],
                        span: c.len(),
                    })
                    .collect();
            }
            lambda -= 0.05;
        }
    }
}

/// FastAdaSP: dominant frames by attention; neighbours merge into the
/// nearest kept frame (multitask-adapted merging).
pub struct FastAdaSp;

impl Reducer for FastAdaSp {
    fn name(&self) -> &'static str {
        "FastAdaSP"
    }

    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken> {
        let n = ctx.n();
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_by(|&a, &b| ctx.importance[b].total_cmp(&ctx.importance[a]));
        let mut kept: Vec<usize> = idx.into_iter().take(ctx.retain).collect();
        kept.sort_unstable();
        // merge each dropped frame into the nearest kept frame by position
        let mut groups: Vec<Vec<usize>> = kept.iter().map(|&k| vec![k]).collect();
        for t in 0..n {
            if kept.binary_search(&t).is_ok() {
                continue;
            }
            let g = match kept.binary_search(&t) {
                Ok(p) => p,
                Err(p) => {
                    if p == 0 {
                        0
                    } else if p >= kept.len() {
                        kept.len() - 1
                    } else if t - kept[p - 1] <= kept[p] - t {
                        p - 1
                    } else {
                        p
                    }
                }
            };
            groups[g].push(t);
        }
        groups
            .iter()
            .zip(&kept)
            .map(|(g, &k)| ReducedToken {
                feature: weighted_merge(ctx.features, g, ctx.importance),
                first_pos: k,
                span: g.len(),
            })
            .collect()
    }
}

/// CDPruner: conditional-diversity pruning (DPP MAP on the relevance-
/// conditioned kernel), no merging.
pub struct CdPruner;

impl Reducer for CdPruner {
    fn name(&self) -> &'static str {
        "CDPruner"
    }

    fn reduce(&self, ctx: &PruneContext) -> Vec<ReducedToken> {
        let n = ctx.n();
        let mut l = vec![vec![0.0f32; n]; n];
        for i in 0..n {
            for j in 0..n {
                l[i][j] = (cosine(&ctx.features[i], &ctx.features[j]) + 1.0) / 2.0;
            }
        }
        let a: Vec<f32> = ctx.importance.iter().map(|&x| x + 0.05).collect();
        let lc = conditional_kernel(&l, &a);
        dpp_map_select(&lc, ctx.retain)
            .into_iter()
            .map(|i| ReducedToken {
                feature: ctx.features[i].clone(),
                first_pos: i,
                span: 1,
            })
            .collect()
    }
}

/// The Table 13 method set (visual pruners reused on audio, as the paper
/// does, via the Pruner->Reducer adapter).
pub fn all_audio_reducers() -> Vec<Box<dyn Reducer>> {
    vec![
        Box::new(PrunerAsReducer(VisionZip)),
        Box::new(PrunerAsReducer(VisPruner)),
        Box::new(CdPruner),
        Box::new(AToMe),
        Box::new(FastAdaSp),
        Box::new(Samp::default()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::AudioSceneGen;

    fn ctx_of(scene: &crate::data::AudioScene, retain: usize) -> PruneContext<'_> {
        PruneContext {
            features: &scene.features,
            importance: &scene.attention,
            retain,
        }
    }

    #[test]
    fn adjacent_clusters_follow_segments() {
        let gen = AudioSceneGen::new(16, 12, 0.05, 0);
        let s = gen.scene(0, 100);
        let clusters = adjacent_clusters(&s.features, 0.8);
        // clusters should roughly match phoneme segments (±50%)
        let segs = s.transcript.len();
        assert!(
            clusters.len() >= segs / 2 && clusters.len() <= segs * 2,
            "{} clusters vs {} segments",
            clusters.len(),
            segs
        );
        // all indices covered exactly once, in order
        let flat: Vec<usize> = clusters.iter().flatten().copied().collect();
        assert_eq!(flat, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn all_reducers_respect_budget() {
        let gen = AudioSceneGen::new(16, 12, 0.1, 1);
        let s = gen.scene(1, 120);
        for r in all_audio_reducers() {
            let reduced = r.reduce(&ctx_of(&s, 72));
            assert!(
                reduced.len() <= 72,
                "{} produced {} tokens",
                r.name(),
                reduced.len()
            );
            assert!(!reduced.is_empty(), "{}", r.name());
        }
    }

    #[test]
    fn samp_merges_before_pruning() {
        let gen = AudioSceneGen::new(16, 12, 0.05, 2);
        let s = gen.scene(0, 150);
        let reduced = Samp::default().reduce(&ctx_of(&s, 90));
        let merged_any = reduced.iter().any(|t| t.span > 1);
        assert!(merged_any, "samp should merge redundant adjacent frames");
        let total_span: usize = reduced.iter().map(|t| t.span).sum();
        assert!(total_span <= 150);
    }

    #[test]
    fn atome_spans_cover_everything() {
        let gen = AudioSceneGen::new(16, 12, 0.05, 3);
        let s = gen.scene(0, 80);
        let reduced = AToMe.reduce(&ctx_of(&s, 40));
        let total: usize = reduced.iter().map(|t| t.span).sum();
        assert_eq!(total, 80, "pure merging preserves all frames");
    }

    #[test]
    fn reducers_preserve_order() {
        let gen = AudioSceneGen::new(16, 12, 0.1, 4);
        let s = gen.scene(0, 100);
        for r in all_audio_reducers() {
            let reduced = r.reduce(&ctx_of(&s, 60));
            assert!(
                reduced.windows(2).all(|w| w[0].first_pos < w[1].first_pos),
                "{} order violated",
                r.name()
            );
        }
    }
}
