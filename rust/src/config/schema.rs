//! Typed config schema over the generic YAML tree — mirrors the paper's
//! four config sections (Fig. 6): global settings, model information,
//! compression algorithm specification, dataset configuration (plus an
//! evaluation section for the automated benchmarking pipeline), and the
//! composable `pipeline:` section (an ordered list of compression-pass
//! stages with per-stage overrides).
//!
//! The legacy single-method form (`compression.method` + algo) desugars to
//! a one-stage pipeline, so every pre-pipeline YAML keeps working and is
//! proven bit-identical to its pipeline spelling by
//! tests/test_pass_pipeline.rs. Pass names are validated against the one
//! static `coordinator::PassRegistry` — there is no second algorithm list
//! here to drift.

use super::yaml::{parse, Yaml};
use crate::coordinator::{PassKind, PassRegistry};
use crate::server::{AdmissionPolicy, ClassPolicy, CrashPoint, FaultPlan, ServeCfg};
use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct GlobalCfg {
    pub save_path: String,
    pub deploy_backend: String,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    /// registry key for the ModelFactory ("tiny-target", "tiny-draft", ...)
    pub name: String,
    /// artifact directory holding *.hlo.txt / weights.bin
    pub artifacts_dir: String,
    pub dtype: String,
}

/// Parameters of one compression stage. Doubles as the legacy
/// `compression:` section (the base every pipeline stage inherits its
/// defaults from) and as the per-stage resolved params.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressionCfg {
    /// method family, resolved from the PassRegistry ("quantization" |
    /// "spec_decode" | "sparse_attn" | "token_prune" | "eval")
    pub method: String,
    /// pass name within the family, e.g. "leptoquant", "gptq", "awq",
    /// "smooth", "tequila", "sherry", "eagle3", "stem", "idpruner", "samp"
    pub algo: String,
    pub bits: u32,
    pub group_size: usize,
    /// LeptoQuant outlier-isolation search grid for alpha (paper: [0, 0.001])
    pub alpha_grid: Vec<f64>,
    /// token-pruning retain ratio / sparse-attn density budget
    pub ratio: f64,
    /// SmoothQuant migration strength (s_c = max|X|^a / max|W|^(1-a))
    pub smooth_alpha: f64,
    /// number of speculative tokens per step (spec decode)
    pub num_speculative_tokens: usize,
    /// low-memory calibration: resident-layer budget (0 = keep everything)
    pub low_memory_budget_layers: usize,
    /// packed storage format for the `pack` pass ("int4" | "2bit" |
    /// "ternary167" | "sherry125")
    pub format: String,
    /// pattern-based per-layer selection for the `pack` pass: substrings
    /// or regexes over weight names (auto-detected, mixable); empty
    /// include = all layers, exclude always wins
    pub include: Vec<String>,
    pub exclude: Vec<String>,
}

/// One stage of the compression pipeline: a registered pass name plus its
/// fully-resolved parameters (config-level defaults + per-stage overrides).
#[derive(Clone, Debug, PartialEq)]
pub struct StageCfg {
    pub pass: String,
    pub params: CompressionCfg,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DatasetCfg {
    pub kind: String,
    pub num_samples: usize,
    pub seq_len: usize,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalCfg {
    pub tasks: Vec<String>,
    pub enabled: bool,
}

/// The full parsed config — one compression job.
#[derive(Clone, Debug, PartialEq)]
pub struct SlimConfig {
    pub global: GlobalCfg,
    pub model: ModelCfg,
    /// the legacy single-method section; also the defaults every pipeline
    /// stage inherits
    pub compression: CompressionCfg,
    /// ordered pipeline stages (>= 1). Absent `pipeline:` desugars the
    /// legacy `compression.method` form into one stage.
    pub pipeline: Vec<StageCfg>,
    pub dataset: DatasetCfg,
    pub eval: EvalCfg,
    /// serving-scheduler knobs (the `serve:` section); defaults to
    /// continuous batching, 8 in flight, unlimited KV budget
    pub serve: ServeCfg,
}

impl SlimConfig {
    pub fn from_str(src: &str) -> Result<Self> {
        let y = parse(src).context("yaml parse")?;
        Self::from_yaml(&y)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_str(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let global = y.get("global").cloned().unwrap_or(Yaml::Null);
        let model = y
            .get("model")
            .context("config missing `model` section")?;
        let comp = y.get("compression").cloned().unwrap_or(Yaml::Null);
        if y.get("compression").is_none() && y.get("pipeline").is_none() {
            bail!("config needs a `compression` section or a `pipeline` section");
        }
        let dataset = y.get("dataset").cloned().unwrap_or(Yaml::Null);
        let eval = y.get("eval").cloned().unwrap_or(Yaml::Null);
        let serve = y.get("serve").cloned().unwrap_or(Yaml::Null);

        let method = comp.str_or("method", "quantization");
        let method_section = comp.get(&method).cloned().unwrap_or(Yaml::Null);
        let default_algo = PassKind::from_method(&method)
            .map(|k| k.default_pass())
            .unwrap_or("none");

        // the legacy method section uses the same strict typed accessors
        // as pipeline stages: a wrong-typed value is a loud error in both
        // spellings, never a silent fall-back to the default. (Unlike
        // `pipeline:` stages, *unknown* keys are tolerated here —
        // AngelSlim-style configs carry extra method-section fields — so
        // only the new stage spelling gets the typo-catching whitelist.)
        let sec = &method_section;
        let label = "compression";
        let compression = CompressionCfg {
            algo: match sec.get("algo") {
                None => default_algo.to_string(),
                Some(v) => v
                    .as_str()
                    .map(String::from)
                    .with_context(|| format!("compression: algo must be a string, got `{v}`"))?,
            },
            bits: match stage_i64(sec, "bits", label)? {
                Some(v) => u32::try_from(v)
                    .map_err(|_| anyhow::anyhow!("compression: bits must be >= 0, got {v}"))?,
                None => 8,
            },
            group_size: match stage_i64(sec, "group_size", label)? {
                Some(v) => non_negative(v, "compression.group_size")?,
                None => 32,
            },
            alpha_grid: alpha_grid_strict(sec, label)?
                .unwrap_or_else(|| vec![0.0, 0.00025, 0.0005, 0.001]),
            ratio: stage_f64(sec, "ratio", label)?.unwrap_or(0.25),
            smooth_alpha: stage_f64(sec, "smooth_alpha", label)?.unwrap_or(0.5),
            num_speculative_tokens: match stage_i64(sec, "num_speculative_tokens", label)? {
                Some(v) => non_negative(v, "compression.num_speculative_tokens")?,
                None => 2,
            },
            low_memory_budget_layers: match stage_i64(sec, "low_memory_budget_layers", label)? {
                Some(v) => non_negative(v, "compression.low_memory_budget_layers")?,
                None => 0,
            },
            format: stage_str(sec, "format", label)?.unwrap_or_else(|| "int4".to_string()),
            include: str_list_strict(sec, "include", label)?.unwrap_or_default(),
            exclude: str_list_strict(sec, "exclude", label)?.unwrap_or_default(),
            method,
        };

        let pipeline = match y.get("pipeline") {
            // legacy single-method form: one stage, params = the
            // compression section verbatim (the claimed method is checked
            // against the registry in validate())
            None => vec![StageCfg {
                pass: compression.algo.clone(),
                params: compression.clone(),
            }],
            Some(Yaml::Seq(items)) => items
                .iter()
                .map(|item| stage_from_yaml(item, &compression))
                .collect::<Result<Vec<_>>>()?,
            Some(other) => bail!(
                "`pipeline` must be a sequence of stages (got {other}); \
                 write `pipeline:` followed by `- pass: <name>` entries"
            ),
        };

        let cfg = SlimConfig {
            global: GlobalCfg {
                save_path: global.str_or("save_path", "./output"),
                deploy_backend: global.str_or("deploy_backend", "angelslim"),
                seed: global.i64_or("seed", 0) as u64,
            },
            model: ModelCfg {
                name: model.str_or("name", "tiny-target"),
                artifacts_dir: model.str_or("artifacts_dir", "artifacts"),
                dtype: model.str_or("dtype", "fp32"),
            },
            compression,
            pipeline,
            dataset: DatasetCfg {
                kind: dataset.str_or("kind", "synthetic"),
                num_samples: dataset.i64_or("num_samples", 64) as usize,
                seq_len: dataset.i64_or("seq_len", 64) as usize,
                seed: dataset.i64_or("seed", 0) as u64,
            },
            eval: EvalCfg {
                tasks: eval
                    .get("tasks")
                    .and_then(Yaml::as_seq)
                    .map(|s| {
                        s.iter()
                            .filter_map(Yaml::as_str)
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_else(|| vec!["perplexity".to_string()]),
                enabled: eval.bool_or("enabled", true),
            },
            serve: serve_from_yaml(&serve)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if PassKind::from_method(&self.compression.method).is_none() {
            bail!(
                "unknown compression method `{}` (have {:?})",
                self.compression.method,
                PassKind::all().map(|k| k.method())
            );
        }
        if self.pipeline.is_empty() {
            bail!("pipeline must contain at least one stage");
        }
        for (i, stage) in self.pipeline.iter().enumerate() {
            let pass = PassRegistry::find(&stage.pass).with_context(|| {
                format!(
                    "pipeline stage {i}: unknown pass `{}` (registered: {:?})",
                    stage.pass,
                    PassRegistry::names()
                )
            })?;
            // the desugared legacy form carries the YAML's claimed method;
            // a mismatch there is the old "algo not registered for method"
            if stage.params.method != pass.kind().method() {
                bail!(
                    "algo `{}` not registered for method `{}` (have {:?})",
                    stage.pass,
                    stage.params.method,
                    PassRegistry::names_for(pass.kind())
                );
            }
            let p = &stage.params;
            if !(1..=16).contains(&p.bits) {
                bail!("stage {i} (`{}`): bits must be in 1..=16, got {}", stage.pass, p.bits);
            }
            if p.ratio <= 0.0 || p.ratio > 1.0 {
                bail!("stage {i} (`{}`): ratio must be in (0, 1], got {}", stage.pass, p.ratio);
            }
            if !(0.0..=1.0).contains(&p.smooth_alpha) {
                bail!(
                    "stage {i} (`{}`): smooth_alpha must be in [0, 1], got {}",
                    stage.pass,
                    p.smooth_alpha
                );
            }
            if p.alpha_grid.is_empty() {
                bail!(
                    "stage {i} (`{}`): alpha_grid must not be empty \
                     (the LeptoQuant search needs at least one candidate)",
                    stage.pass
                );
            }
            if crate::quant::packing::PackFormat::parse(&p.format).is_none() {
                bail!(
                    "stage {i} (`{}`): unknown pack format `{}` \
                     (have f32, f16, int4, 2bit, ternary167, sherry125)",
                    stage.pass,
                    p.format
                );
            }
            crate::util::Selector::new(&p.include, &p.exclude).with_context(|| {
                format!("stage {i} (`{}`): bad include/exclude layer pattern", stage.pass)
            })?;
        }
        if self.dataset.seq_len == 0 || self.dataset.num_samples == 0 {
            bail!("dataset must be non-empty");
        }
        if self.serve.max_in_flight == 0 {
            bail!("serve.max_in_flight must be >= 1");
        }
        if self.serve.workers == 0 {
            bail!("serve.workers must be >= 1 (scheduler worker count)");
        }
        if self.serve.kv_budget_bytes > 0 && self.serve.kv_budget_bytes < self.serve.workers {
            bail!(
                "serve.kv_budget_bytes = {} splits to zero across {} workers; \
                 raise the budget, reduce workers, or set 0 for unlimited",
                self.serve.kv_budget_bytes,
                self.serve.workers
            );
        }
        if self.serve.kv_block_tokens == Some(0) {
            bail!(
                "serve.kv_block_tokens must be >= 1 (tokens per KV page); \
                 omit the key for contiguous KV serving"
            );
        }
        if let Some(d) = self.serve.deadline_ms {
            if d.is_nan() || d <= 0.0 {
                bail!(
                    "serve.deadline_ms must be > 0 (virtual-clock milliseconds \
                     from arrival), got {d}; omit the key for no deadline"
                );
            }
        }
        if self.serve.retry_backoff_ms.is_nan() || self.serve.retry_backoff_ms < 0.0 {
            bail!(
                "serve.retry_backoff_ms must be >= 0, got {}",
                self.serve.retry_backoff_ms
            );
        }
        if !self.serve.max_backoff_ms.is_finite() || self.serve.max_backoff_ms < 0.0 {
            bail!(
                "serve.max_backoff_ms must be a finite number >= 0, got {}; \
                 the cap keeps exponential retry backoff admissible",
                self.serve.max_backoff_ms
            );
        }
        if let Some(plan) = &self.serve.fault {
            plan.validate(self.serve.workers)
                .context("serve.fault: invalid fault plan")?;
        }
        if let Some(policy) = &self.serve.classes {
            policy
                .validate()
                .context("serve.classes: invalid class policy")?;
        }
        Ok(())
    }
}

/// Parse the `serve:` section — scheduler knobs plus the fault-tolerance
/// surface (`deadline_ms`, `max_retries`, `retry_backoff_ms`, nested
/// `fault:` block). Retry knobs without a `fault:` block are dead config
/// (nothing injects faults in a plain run) and are rejected loudly.
fn serve_from_yaml(serve: &Yaml) -> Result<ServeCfg> {
    let fault = fault_from_yaml(serve)?;
    let classes = classes_from_yaml(serve)?;
    if fault.is_none() {
        for knob in ["max_retries", "retry_backoff_ms", "max_backoff_ms"] {
            if serve.get(knob).is_some() {
                bail!(
                    "serve.{knob} is set but there is no `serve.fault:` block; \
                     retries only apply under fault injection — remove the knob \
                     or add a fault block"
                );
            }
        }
    }
    let deadline_ms = match serve.get("deadline_ms") {
        None => None,
        Some(v) => Some(v.as_f64().with_context(|| {
            format!("serve: deadline_ms must be a number, got `{v}`")
        })?),
    };
    let kv_block_tokens = match serve.get("kv_block_tokens") {
        None => None,
        Some(v) => {
            let n = v.as_i64().with_context(|| {
                format!("serve: kv_block_tokens must be an integer, got `{v}`")
            })?;
            if n < 1 {
                bail!(
                    "serve.kv_block_tokens must be >= 1 (tokens per KV page), \
                     got {n}; omit the key for contiguous KV serving"
                );
            }
            Some(n as usize)
        }
    };
    let threads = match serve.get("threads") {
        None => false,
        Some(v) => v
            .as_bool()
            .with_context(|| format!("serve: threads must be a boolean, got `{v}`"))?,
    };
    Ok(ServeCfg {
        policy: AdmissionPolicy::parse(&serve.str_or("policy", "continuous"))?,
        max_in_flight: non_negative(serve.i64_or("max_in_flight", 8), "serve.max_in_flight")?,
        kv_budget_bytes: non_negative(
            serve.i64_or("kv_budget_bytes", 0),
            "serve.kv_budget_bytes",
        )?,
        workers: non_negative(serve.i64_or("workers", 1), "serve.workers")?,
        kv_block_tokens,
        threads,
        deadline_ms,
        max_retries: match stage_i64(serve, "max_retries", "serve")? {
            Some(v) => non_negative(v, "serve.max_retries")?,
            None => 0,
        },
        retry_backoff_ms: stage_f64(serve, "retry_backoff_ms", "serve")?.unwrap_or(1.0),
        max_backoff_ms: stage_f64(serve, "max_backoff_ms", "serve")?.unwrap_or(60_000.0),
        fault,
        classes,
    })
}

/// The knobs a `serve.fault:` block may carry — anything else (an unknown
/// fault kind, a typo) is a loud error, never silently ignored chaos.
const FAULT_KEYS: &[&str] = &[
    "seed",
    "step_error_rate",
    "nan_rate",
    "stall_rate",
    "stall_ms",
    "crash_worker",
    "crash_at_ms",
];

fn fault_from_yaml(serve: &Yaml) -> Result<Option<FaultPlan>> {
    let fault = match serve.get("fault") {
        None => return Ok(None),
        Some(f) => f,
    };
    match fault {
        Yaml::Map(m) => {
            if let Some(unknown) = m.keys().find(|k| !FAULT_KEYS.contains(&k.as_str())) {
                bail!(
                    "serve.fault: unknown fault knob `{unknown}` \
                     (allowed: {FAULT_KEYS:?})"
                );
            }
        }
        other => bail!("serve.fault must be a map of fault knobs, got `{other}`"),
    }
    let scope = "serve.fault";
    let mut plan = FaultPlan::default();
    if let Some(v) = stage_i64(fault, "seed", scope)? {
        plan.seed = non_negative(v, "serve.fault.seed")? as u64;
    }
    if let Some(v) = stage_f64(fault, "step_error_rate", scope)? {
        plan.step_error_rate = v;
    }
    if let Some(v) = stage_f64(fault, "nan_rate", scope)? {
        plan.nan_rate = v;
    }
    if let Some(v) = stage_f64(fault, "stall_rate", scope)? {
        plan.stall_rate = v;
    }
    if let Some(v) = stage_f64(fault, "stall_ms", scope)? {
        plan.stall_ms = v;
    }
    let crash_worker = stage_i64(fault, "crash_worker", scope)?;
    let crash_at_ms = stage_f64(fault, "crash_at_ms", scope)?;
    match (crash_worker, crash_at_ms) {
        (None, None) => {}
        (Some(w), Some(at_ms)) => plan.crashes.push(CrashPoint {
            worker: non_negative(w, "serve.fault.crash_worker")?,
            at_ms,
        }),
        _ => bail!(
            "serve.fault: crash_worker and crash_at_ms must be set together \
             (a crash needs both a target worker and a virtual time)"
        ),
    }
    Ok(Some(plan))
}

/// The knobs a `serve.classes:` block may carry — the four class names
/// plus the aging/routing knobs. Anything else (a typo like
/// `intractive:`) is a loud error, never a silently ignored SLO.
const CLASS_KEYS: &[&str] = &[
    "interactive",
    "long_context",
    "multimodal",
    "batch",
    "aging_ms",
    "sparse_block",
    "sparse_budget",
    "multimodal_retain",
];

/// The knobs one class entry may carry.
const CLASS_SLO_KEYS: &[&str] = &["ttft_slo_ms", "latency_slo_ms", "deadline_ms", "priority"];

/// Parse the nested `serve.classes:` block into a [`ClassPolicy`]. Every
/// knob defaults from [`ClassPolicy::default`], so a bare `classes: {}` or
/// a partial block (only the classes you want to re-tune) is valid; the
/// assembled policy is range-checked by `ClassPolicy::validate` in
/// [`SlimConfig::validate`].
fn classes_from_yaml(serve: &Yaml) -> Result<Option<ClassPolicy>> {
    let classes = match serve.get("classes") {
        None => return Ok(None),
        Some(c) => c,
    };
    match classes {
        Yaml::Map(m) => {
            if let Some(unknown) = m.keys().find(|k| !CLASS_KEYS.contains(&k.as_str())) {
                bail!(
                    "serve.classes: unknown knob `{unknown}` \
                     (allowed: {CLASS_KEYS:?})"
                );
            }
        }
        // a bare `classes:` key enables the default policy
        Yaml::Null => return Ok(Some(ClassPolicy::default())),
        other => bail!("serve.classes must be a map of class knobs, got `{other}`"),
    }
    let scope = "serve.classes";
    let mut policy = ClassPolicy::default();
    if let Some(v) = stage_f64(classes, "aging_ms", scope)? {
        policy.aging_ms = v;
    }
    if let Some(v) = stage_i64(classes, "sparse_block", scope)? {
        policy.sparse_block = non_negative(v, "serve.classes.sparse_block")?;
    }
    if let Some(v) = stage_f64(classes, "sparse_budget", scope)? {
        policy.sparse_budget = v;
    }
    if let Some(v) = stage_f64(classes, "multimodal_retain", scope)? {
        policy.multimodal_retain = v;
    }
    for (name, slo) in [
        ("interactive", &mut policy.interactive),
        ("long_context", &mut policy.long_context),
        ("multimodal", &mut policy.multimodal),
        ("batch", &mut policy.batch),
    ] {
        let entry = match classes.get(name) {
            None => continue,
            Some(e) => e,
        };
        match entry {
            Yaml::Map(m) => {
                if let Some(unknown) =
                    m.keys().find(|k| !CLASS_SLO_KEYS.contains(&k.as_str()))
                {
                    bail!(
                        "serve.classes.{name}: unknown knob `{unknown}` \
                         (allowed: {CLASS_SLO_KEYS:?})"
                    );
                }
            }
            other => bail!(
                "serve.classes.{name} must be a map of SLO knobs, got `{other}`"
            ),
        }
        let scope = format!("serve.classes.{name}");
        if let Some(v) = stage_f64(entry, "ttft_slo_ms", &scope)? {
            slo.ttft_slo_ms = v;
        }
        if let Some(v) = stage_f64(entry, "latency_slo_ms", &scope)? {
            slo.latency_slo_ms = v;
        }
        if let Some(v) = stage_f64(entry, "deadline_ms", &scope)? {
            slo.deadline_ms = Some(v);
        }
        if let Some(v) = stage_i64(entry, "priority", &scope)? {
            slo.priority = u8::try_from(v).map_err(|_| {
                anyhow::anyhow!("{scope}.priority must be in 0..=255, got {v}")
            })?;
        }
    }
    Ok(Some(policy))
}

/// The per-stage override keys a `pipeline:` entry may carry. A key
/// outside this list (a typo like `smooth_aplha`) or a value of the wrong
/// YAML type is a loud error, not a silent fallback to the default.
const STAGE_KEYS: &[&str] = &[
    "pass",
    "bits",
    "group_size",
    "ratio",
    "smooth_alpha",
    "num_speculative_tokens",
    "low_memory_budget_layers",
    "alpha_grid",
    "format",
    "include",
    "exclude",
];

/// Parse one `pipeline:` entry — either a bare pass name (`- gptq`) or a
/// map with per-stage overrides (`- pass: gptq` + `group_size: 64` ...).
fn stage_from_yaml(item: &Yaml, base: &CompressionCfg) -> Result<StageCfg> {
    let (name, overrides): (&str, &Yaml) = match item {
        Yaml::Str(s) => (s.as_str(), &Yaml::Null),
        Yaml::Map(m) => {
            let name = item
                .get("pass")
                .and_then(Yaml::as_str)
                .context("pipeline stage missing `pass: <name>`")?;
            if let Some(unknown) = m.keys().find(|k| !STAGE_KEYS.contains(&k.as_str())) {
                bail!(
                    "stage `{name}`: unknown override `{unknown}` (allowed: {STAGE_KEYS:?})"
                );
            }
            (name, item)
        }
        other => bail!(
            "pipeline stage must be a pass name or a `pass:` map, got `{other}`"
        ),
    };
    let mut params = base.clone();
    params.algo = name.to_string();
    // resolve the method family from the registry; unknown names keep the
    // base method and fail loudly in validate() with the full name list
    if let Some(pass) = PassRegistry::find(name) {
        params.method = pass.kind().method().to_string();
    }
    let scope = format!("stage `{name}`");
    if let Some(v) = stage_i64(overrides, "bits", &scope)? {
        params.bits = u32::try_from(v)
            .map_err(|_| anyhow::anyhow!("{scope}: bits must be >= 0, got {v}"))?;
    }
    if let Some(v) = stage_i64(overrides, "group_size", &scope)? {
        params.group_size = non_negative(v, &format!("{scope}: group_size"))?;
    }
    if let Some(v) = stage_f64(overrides, "ratio", &scope)? {
        params.ratio = v;
    }
    if let Some(v) = stage_f64(overrides, "smooth_alpha", &scope)? {
        params.smooth_alpha = v;
    }
    if let Some(v) = stage_i64(overrides, "num_speculative_tokens", &scope)? {
        params.num_speculative_tokens =
            non_negative(v, &format!("{scope}: num_speculative_tokens"))?;
    }
    if let Some(v) = stage_i64(overrides, "low_memory_budget_layers", &scope)? {
        params.low_memory_budget_layers =
            non_negative(v, &format!("{scope}: low_memory_budget_layers"))?;
    }
    if let Some(grid) = alpha_grid_strict(overrides, &scope)? {
        params.alpha_grid = grid;
    }
    if let Some(v) = stage_str(overrides, "format", &scope)? {
        params.format = v;
    }
    if let Some(v) = str_list_strict(overrides, "include", &scope)? {
        params.include = v;
    }
    if let Some(v) = str_list_strict(overrides, "exclude", &scope)? {
        params.exclude = v;
    }
    Ok(StageCfg { pass: name.to_string(), params })
}

/// Typed override accessors shared by the legacy `compression:` section
/// and `pipeline:` stages: absent key → None; present with the wrong
/// YAML type → loud error (never a silent default).
fn stage_i64(section: &Yaml, key: &str, scope: &str) -> Result<Option<i64>> {
    match section.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_i64().with_context(|| {
            format!("{scope}: {key} must be an integer, got `{v}`")
        })?)),
    }
}

fn stage_f64(section: &Yaml, key: &str, scope: &str) -> Result<Option<f64>> {
    match section.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_f64().with_context(|| {
            format!("{scope}: {key} must be a number, got `{v}`")
        })?)),
    }
}

fn stage_str(section: &Yaml, key: &str, scope: &str) -> Result<Option<String>> {
    match section.get(key) {
        None => Ok(None),
        Some(v) => Ok(Some(v.as_str().map(String::from).with_context(|| {
            format!("{scope}: {key} must be a string, got `{v}`")
        })?)),
    }
}

/// Strict string-list accessor (include/exclude layer patterns): present
/// but not a list, or non-string entries, are loud errors; absent → None.
fn str_list_strict(section: &Yaml, key: &str, scope: &str) -> Result<Option<Vec<String>>> {
    match section.get(key) {
        None => Ok(None),
        Some(list) => {
            let seq = list.as_seq().with_context(|| {
                format!("{scope}: {key} must be a list of strings, got `{list}`")
            })?;
            seq.iter()
                .map(|v| {
                    v.as_str().map(String::from).with_context(|| {
                        format!("{scope}: {key} entries must be strings, got `{v}`")
                    })
                })
                .collect::<Result<Vec<String>>>()
                .map(Some)
        }
    }
}

/// Strict alpha_grid: present-but-not-a-list or non-numeric entries are
/// loud errors; absent → None (caller applies the default).
fn alpha_grid_strict(section: &Yaml, scope: &str) -> Result<Option<Vec<f64>>> {
    match section.get("alpha_grid") {
        None => Ok(None),
        Some(grid) => {
            let seq = grid
                .as_seq()
                .with_context(|| format!("{scope}: alpha_grid must be a list, got `{grid}`"))?;
            let vals = seq
                .iter()
                .map(|v| {
                    v.as_f64().with_context(|| {
                        format!("{scope}: alpha_grid entries must be numbers, got `{v}`")
                    })
                })
                .collect::<Result<Vec<f64>>>()?;
            Ok(Some(vals))
        }
    }
}

/// Reject negative config values instead of letting `as usize` wrap them
/// into huge limits that silently disable the knob they configure.
fn non_negative(v: i64, name: &str) -> Result<usize> {
    if v < 0 {
        bail!("{name} must be >= 0, got {v}");
    }
    Ok(v as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
global:
  save_path: ./out
  deploy_backend: vllm
  seed: 7
model:
  name: tiny-target
  artifacts_dir: artifacts
  dtype: fp32
compression:
  method: quantization
  quantization:
    algo: leptoquant
    bits: 8
    group_size: 64
    alpha_grid: [0.0, 0.001]
dataset:
  kind: synthetic
  num_samples: 32
  seq_len: 48
eval:
  enabled: true
  tasks:
    - perplexity
    - copy
serve:
  policy: static
  max_in_flight: 4
  kv_budget_bytes: 65536
  workers: 2
"#;

    #[test]
    fn full_roundtrip() {
        let c = SlimConfig::from_str(FULL).unwrap();
        assert_eq!(c.global.seed, 7);
        assert_eq!(c.compression.algo, "leptoquant");
        assert_eq!(c.compression.group_size, 64);
        assert_eq!(c.compression.alpha_grid, vec![0.0, 0.001]);
        assert_eq!(c.dataset.seq_len, 48);
        assert_eq!(c.eval.tasks, vec!["perplexity", "copy"]);
        assert_eq!(c.serve.policy, AdmissionPolicy::Static);
        assert_eq!(c.serve.max_in_flight, 4);
        assert_eq!(c.serve.kv_budget_bytes, 65536);
        assert_eq!(c.serve.workers, 2);
        // legacy form desugars to a one-stage pipeline
        assert_eq!(c.pipeline.len(), 1);
        assert_eq!(c.pipeline[0].pass, "leptoquant");
        assert_eq!(c.pipeline[0].params, c.compression);
    }

    #[test]
    fn defaults_fill_in() {
        let c = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: sparse_attn\n",
        )
        .unwrap();
        assert_eq!(c.compression.algo, "stem");
        assert_eq!(c.dataset.num_samples, 64);
        assert!(c.eval.enabled);
        assert_eq!(c.serve.policy, AdmissionPolicy::Continuous);
        assert_eq!(c.serve.max_in_flight, 8);
        assert_eq!(c.serve.kv_budget_bytes, 0);
        assert_eq!(c.serve.workers, 1, "single worker unless configured");
        assert_eq!(c.pipeline[0].pass, "stem");
    }

    #[test]
    fn pipeline_section_parses_stages_with_overrides() {
        let c = SlimConfig::from_str(
            "model:\n  name: tiny-fixture\n\
             pipeline:\n\
             \x20 - pass: smooth\n    smooth_alpha: 0.4\n\
             \x20 - pass: gptq\n    group_size: 64\n    low_memory_budget_layers: 1\n\
             \x20 - eval\n",
        )
        .unwrap();
        assert_eq!(c.pipeline.len(), 3);
        assert_eq!(c.pipeline[0].pass, "smooth");
        assert_eq!(c.pipeline[0].params.method, "quantization");
        assert!((c.pipeline[0].params.smooth_alpha - 0.4).abs() < 1e-12);
        assert_eq!(c.pipeline[1].params.group_size, 64);
        assert_eq!(c.pipeline[1].params.low_memory_budget_layers, 1);
        // bare scalar stage + method resolved from the registry
        assert_eq!(c.pipeline[2].pass, "eval");
        assert_eq!(c.pipeline[2].params.method, "eval");
        // stage 0 inherited the default where not overridden
        assert_eq!(c.pipeline[0].params.group_size, 32);
    }

    #[test]
    fn pipeline_rejects_unknown_pass_and_empty() {
        let err = SlimConfig::from_str(
            "model:\n  name: m\npipeline:\n  - pass: wizardry\n",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("wizardry"), "{err:#}");
        assert!(SlimConfig::from_str("model:\n  name: m\npipeline: []\n").is_err());
        assert!(
            SlimConfig::from_str("model:\n  name: m\npipeline: gptq\n").is_err(),
            "scalar pipeline must be rejected with guidance"
        );
    }

    #[test]
    fn pipeline_rejects_invalid_stage_overrides() {
        for bad in [
            "  - pass: int4\n    bits: 99\n",
            "  - pass: idpruner\n    ratio: 0.0\n",
            "  - pass: smooth\n    smooth_alpha: 1.5\n",
            "  - pass: gptq\n    low_memory_budget_layers: -1\n",
            "  - pass: gptq\n    bits: -4\n",
        ] {
            let r = SlimConfig::from_str(&format!("model:\n  name: m\npipeline:\n{bad}"));
            assert!(r.is_err(), "override must fail loudly: {bad}");
        }
    }

    #[test]
    fn pipeline_rejects_wrong_typed_and_unknown_overrides() {
        for (bad, why) in [
            ("  - pass: idpruner\n    ratio: fast\n", "string ratio"),
            ("  - pass: int4\n    bits: 4.5\n", "float bits"),
            ("  - pass: smooth\n    smooth_aplha: 0.9\n", "typoed key"),
            ("  - pass: leptoquant\n    alpha_grid: 3\n", "scalar alpha_grid"),
            ("  - pass: leptoquant\n    alpha_grid: [a, b]\n", "non-numeric grid"),
        ] {
            let r = SlimConfig::from_str(&format!("model:\n  name: m\npipeline:\n{bad}"));
            assert!(r.is_err(), "{why} must fail loudly, not fall back to the default");
        }
        // integers are valid floats for f64 overrides
        let c = SlimConfig::from_str(
            "model:\n  name: m\npipeline:\n  - pass: idpruner\n    ratio: 1\n",
        )
        .unwrap();
        assert!((c.pipeline[0].params.ratio - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pack_stage_knobs_parse_and_validate() {
        let c = SlimConfig::from_str(
            "model:\n  name: tiny-fixture\n\
             pipeline:\n\
             \x20 - pass: pack\n    format: 2bit\n    include: [w_gate, w_up]\n    exclude: [layer1]\n",
        )
        .unwrap();
        assert_eq!(c.pipeline[0].params.format, "2bit");
        assert_eq!(c.pipeline[0].params.include, vec!["w_gate", "w_up"]);
        assert_eq!(c.pipeline[0].params.exclude, vec!["layer1"]);
        // defaults: int4, empty selectors
        let d = SlimConfig::from_str("model:\n  name: m\npipeline:\n  - pass: pack\n").unwrap();
        assert_eq!(d.pipeline[0].params.format, "int4");
        assert!(d.pipeline[0].params.include.is_empty());

        for (bad, why) in [
            ("  - pass: pack\n    format: int3\n", "unknown format"),
            ("  - pass: pack\n    format: [int4]\n", "non-string format"),
            ("  - pass: pack\n    include: wq\n", "scalar include"),
            ("  - pass: pack\n    include: [4]\n", "non-string include entry"),
            ("  - pass: pack\n    exclude: ['(bad']\n", "uncompilable pattern"),
        ] {
            let r = SlimConfig::from_str(&format!("model:\n  name: m\npipeline:\n{bad}"));
            assert!(r.is_err(), "{why} must fail loudly");
        }
    }

    #[test]
    fn legacy_section_is_equally_strict_about_types() {
        // the same misconfiguration must fail identically in both
        // spellings — no silent fall-back in the legacy form either
        for bad in [
            "    ratio: fast\n",
            "    bits: 4.5\n",
            "    alpha_grid: [a, b]\n",
            "    alpha_grid: []\n",
        ] {
            let src = format!(
                "model:\n  name: m\ncompression:\n  method: quantization\n  quantization:\n\
                 \x20   algo: leptoquant\n{bad}"
            );
            assert!(SlimConfig::from_str(&src).is_err(), "legacy form must reject: {bad:?}");
        }
        // wrong-typed algo must not silently fall back to the default pass
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\n  quantization:\n    algo: 4\n",
        );
        assert!(r.is_err(), "non-string algo must be rejected, not defaulted");
    }

    #[test]
    fn legacy_method_algo_mismatch_is_loud() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\n  quantization:\n    algo: stem\n",
        );
        let err = format!("{:#}", r.unwrap_err());
        assert!(err.contains("not registered for method"), "{err}");
    }

    #[test]
    fn rejects_unknown_serve_policy() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\nserve:\n  policy: psychic\n",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_negative_serve_values() {
        for field in ["max_in_flight", "kv_budget_bytes", "workers"] {
            let r = SlimConfig::from_str(&format!(
                "model:\n  name: m\ncompression:\n  method: quantization\nserve:\n  {field}: -1\n",
            ));
            assert!(r.is_err(), "negative {field} must not wrap to usize::MAX");
        }
    }

    // zero-worker and budget-splits-to-zero rejections are covered at the
    // integration level in tests/test_configs.rs (which also exercises the
    // executor-aware ensure_requests_fit guard)

    #[test]
    fn serve_kv_block_tokens_parses_and_rejects_nonsense() {
        let c = serve_cfg("  kv_block_tokens: 16\n").unwrap();
        assert_eq!(c.serve.kv_block_tokens, Some(16));
        let d = serve_cfg("  workers: 2\n").unwrap();
        assert_eq!(d.serve.kv_block_tokens, None, "absent key stays contiguous");
        for (bad, why) in [
            ("  kv_block_tokens: 0\n", "zero page size"),
            ("  kv_block_tokens: -4\n", "negative page size"),
            ("  kv_block_tokens: huge\n", "non-numeric page size"),
        ] {
            assert!(serve_cfg(bad).is_err(), "{why} must fail loudly: {bad:?}");
        }
    }

    fn serve_cfg(serve_yaml: &str) -> Result<SlimConfig> {
        SlimConfig::from_str(&format!(
            "model:\n  name: m\ncompression:\n  method: quantization\nserve:\n{serve_yaml}"
        ))
    }

    #[test]
    fn serve_fault_block_parses_into_a_plan() {
        let c = serve_cfg(
            "  workers: 2\n  deadline_ms: 40\n  max_retries: 3\n  retry_backoff_ms: 2.5\n\
             \x20 fault:\n    seed: 11\n    step_error_rate: 0.1\n    nan_rate: 0.05\n\
             \x20   stall_rate: 0.2\n    stall_ms: 4\n    crash_worker: 1\n    crash_at_ms: 9.5\n",
        )
        .unwrap();
        assert_eq!(c.serve.deadline_ms, Some(40.0));
        assert_eq!(c.serve.max_retries, 3);
        assert!((c.serve.retry_backoff_ms - 2.5).abs() < 1e-12);
        let plan = c.serve.fault.expect("fault block parsed");
        assert_eq!(plan.seed, 11);
        assert!((plan.step_error_rate - 0.1).abs() < 1e-12);
        assert!((plan.nan_rate - 0.05).abs() < 1e-12);
        assert_eq!(plan.crashes, vec![CrashPoint { worker: 1, at_ms: 9.5 }]);
        // no fault block → no plan, retry defaults
        let d = serve_cfg("  workers: 2\n").unwrap();
        assert!(d.serve.fault.is_none());
        assert_eq!(d.serve.max_retries, 0);
        assert_eq!(d.serve.deadline_ms, None);
    }

    #[test]
    fn serve_rejects_misconfigured_fault_tolerance() {
        for (bad, why) in [
            ("  deadline_ms: 0\n", "zero deadline"),
            ("  deadline_ms: -5\n", "negative deadline"),
            ("  deadline_ms: soon\n", "non-numeric deadline"),
            ("  max_retries: 2\n", "retries without a fault block"),
            ("  retry_backoff_ms: 1\n", "backoff without a fault block"),
            (
                "  fault:\n    seed: 1\n  retry_backoff_ms: -1\n",
                "negative backoff",
            ),
            ("  fault:\n    flux_capacitor: 0.5\n", "unknown fault knob"),
            ("  fault:\n    step_error_rate: 1.5\n", "rate above 1"),
            ("  fault:\n    nan_rate: -0.1\n", "negative rate"),
            ("  fault:\n    stall_ms: -2\n", "negative stall"),
            ("  fault:\n    crash_worker: 0\n", "crash_worker without crash_at_ms"),
            ("  fault:\n    crash_at_ms: 5\n", "crash_at_ms without crash_worker"),
            (
                "  workers: 2\n  fault:\n    crash_worker: 2\n    crash_at_ms: 5\n",
                "crash target out of range",
            ),
            ("  fault: chaos\n", "scalar fault block"),
        ] {
            assert!(serve_cfg(bad).is_err(), "{why} must fail loudly: {bad:?}");
        }
        // a valid crash pair on an in-range worker parses
        assert!(serve_cfg(
            "  workers: 2\n  fault:\n    crash_worker: 1\n    crash_at_ms: 5\n"
        )
        .is_ok());
    }

    #[test]
    fn serve_classes_block_parses_into_a_policy() {
        let c = serve_cfg(
            "  classes:\n    aging_ms: 250\n    sparse_block: 8\n    sparse_budget: 0.25\n\
             \x20   multimodal_retain: 0.75\n    interactive:\n      ttft_slo_ms: 20\n\
             \x20     latency_slo_ms: 200\n      priority: 5\n    batch:\n\
             \x20     deadline_ms: 9000\n",
        )
        .unwrap();
        let p = c.serve.classes.expect("classes block parsed");
        assert!((p.aging_ms - 250.0).abs() < 1e-12);
        assert_eq!(p.sparse_block, 8);
        assert!((p.sparse_budget - 0.25).abs() < 1e-12);
        assert!((p.multimodal_retain - 0.75).abs() < 1e-12);
        assert!((p.interactive.ttft_slo_ms - 20.0).abs() < 1e-12);
        assert!((p.interactive.latency_slo_ms - 200.0).abs() < 1e-12);
        assert_eq!(p.interactive.priority, 5);
        assert_eq!(p.batch.deadline_ms, Some(9000.0));
        // untouched classes keep the documented defaults
        let d = crate::server::ClassPolicy::default();
        assert_eq!(p.long_context, d.long_context);
        assert_eq!(p.multimodal, d.multimodal);
        // no classes block → no policy (class-blind FIFO)
        assert!(serve_cfg("  workers: 2\n").unwrap().serve.classes.is_none());
        // a bare `classes:` key enables the default policy
        let e = serve_cfg("  classes:\n").unwrap();
        assert_eq!(e.serve.classes, Some(d));
    }

    #[test]
    fn serve_classes_rejects_misconfiguration() {
        for (bad, why) in [
            ("  classes:\n    intractive:\n      priority: 1\n", "typo'd class name"),
            (
                "  classes:\n    interactive:\n      ttft_slo: 5\n",
                "typo'd SLO knob",
            ),
            ("  classes: fast\n", "scalar classes block"),
            ("  classes:\n    interactive: fast\n", "scalar class entry"),
            (
                "  classes:\n    interactive:\n      priority: 300\n",
                "priority above 255",
            ),
            (
                "  classes:\n    interactive:\n      ttft_slo_ms: 0\n",
                "zero TTFT SLO",
            ),
            (
                "  classes:\n    batch:\n      deadline_ms: -1\n",
                "negative class deadline",
            ),
            ("  classes:\n    aging_ms: -5\n", "negative aging bound"),
            ("  classes:\n    sparse_block: 0\n", "zero sparse block"),
            ("  classes:\n    sparse_budget: 1.5\n", "sparse budget above 1"),
            (
                "  classes:\n    multimodal_retain: 0\n",
                "zero multimodal retain",
            ),
        ] {
            assert!(serve_cfg(bad).is_err(), "{why} must fail loudly: {bad:?}");
        }
    }

    #[test]
    fn rejects_unknown_method() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: teleport\n",
        );
        let err = format!("{:#}", r.unwrap_err());
        assert!(err.contains("unknown compression method"), "{err}");
    }

    #[test]
    fn rejects_bad_bits() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\n  quantization:\n    bits: 99\n",
        );
        assert!(r.is_err());
    }

    #[test]
    fn missing_model_errors() {
        assert!(SlimConfig::from_str("compression:\n  method: quantization\n").is_err());
    }

    #[test]
    fn missing_compression_and_pipeline_errors() {
        assert!(SlimConfig::from_str("model:\n  name: m\n").is_err());
    }
}
