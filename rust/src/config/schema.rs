//! Typed config schema over the generic YAML tree — mirrors the paper's
//! four config sections (Fig. 6): global settings, model information,
//! compression algorithm specification, dataset configuration (plus an
//! evaluation section for the automated benchmarking pipeline).

use super::yaml::{parse, Yaml};
use crate::server::{AdmissionPolicy, ServeCfg};
use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct GlobalCfg {
    pub save_path: String,
    pub deploy_backend: String,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ModelCfg {
    /// registry key for the ModelFactory ("tiny-target", "tiny-draft", ...)
    pub name: String,
    /// artifact directory holding *.hlo.txt / weights.bin
    pub artifacts_dir: String,
    pub dtype: String,
}

#[derive(Clone, Debug, PartialEq)]
pub struct CompressionCfg {
    /// "quantization" | "spec_decode" | "sparse_attn" | "token_prune"
    pub method: String,
    /// algorithm within the method, e.g. "leptoquant", "gptq", "awq",
    /// "fp8_dynamic", "seq2", "tequila", "sherry", "eagle3", "stem",
    /// "idpruner", "samp"
    pub algo: String,
    pub bits: u32,
    pub group_size: usize,
    /// LeptoQuant outlier-isolation search grid for alpha (paper: [0, 0.001])
    pub alpha_grid: Vec<f64>,
    /// token-pruning retain ratio / sparse-attn density budget
    pub ratio: f64,
    /// number of speculative tokens per step (spec decode)
    pub num_speculative_tokens: usize,
    /// low-memory calibration: resident-layer budget (0 = keep everything)
    pub low_memory_budget_layers: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct DatasetCfg {
    pub kind: String,
    pub num_samples: usize,
    pub seq_len: usize,
    pub seed: u64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalCfg {
    pub tasks: Vec<String>,
    pub enabled: bool,
}

/// The full parsed config — one compression job.
#[derive(Clone, Debug, PartialEq)]
pub struct SlimConfig {
    pub global: GlobalCfg,
    pub model: ModelCfg,
    pub compression: CompressionCfg,
    pub dataset: DatasetCfg,
    pub eval: EvalCfg,
    /// serving-scheduler knobs (the `serve:` section); defaults to
    /// continuous batching, 8 in flight, unlimited KV budget
    pub serve: ServeCfg,
}

impl SlimConfig {
    pub fn from_str(src: &str) -> Result<Self> {
        let y = parse(src).context("yaml parse")?;
        Self::from_yaml(&y)
    }

    pub fn from_file(path: &str) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        Self::from_str(&src)
    }

    pub fn from_yaml(y: &Yaml) -> Result<Self> {
        let global = y.get("global").cloned().unwrap_or(Yaml::Null);
        let model = y
            .get("model")
            .context("config missing `model` section")?;
        let comp = y
            .get("compression")
            .context("config missing `compression` section")?;
        let dataset = y.get("dataset").cloned().unwrap_or(Yaml::Null);
        let eval = y.get("eval").cloned().unwrap_or(Yaml::Null);
        let serve = y.get("serve").cloned().unwrap_or(Yaml::Null);

        let method = comp.str_or("method", "quantization");
        let method_section = comp.get(&method).cloned().unwrap_or(Yaml::Null);

        let alpha_grid = method_section
            .get("alpha_grid")
            .and_then(Yaml::as_seq)
            .map(|s| s.iter().filter_map(Yaml::as_f64).collect())
            .unwrap_or_else(|| vec![0.0, 0.00025, 0.0005, 0.001]);

        let cfg = SlimConfig {
            global: GlobalCfg {
                save_path: global.str_or("save_path", "./output"),
                deploy_backend: global.str_or("deploy_backend", "angelslim"),
                seed: global.i64_or("seed", 0) as u64,
            },
            model: ModelCfg {
                name: model.str_or("name", "tiny-target"),
                artifacts_dir: model.str_or("artifacts_dir", "artifacts"),
                dtype: model.str_or("dtype", "fp32"),
            },
            compression: CompressionCfg {
                algo: method_section.str_or("algo", default_algo(&method)),
                bits: method_section.i64_or("bits", 8) as u32,
                group_size: method_section.i64_or("group_size", 32) as usize,
                alpha_grid,
                ratio: method_section.f64_or("ratio", 0.25),
                num_speculative_tokens: method_section
                    .i64_or("num_speculative_tokens", 2)
                    as usize,
                low_memory_budget_layers: method_section
                    .i64_or("low_memory_budget_layers", 0)
                    as usize,
                method,
            },
            dataset: DatasetCfg {
                kind: dataset.str_or("kind", "synthetic"),
                num_samples: dataset.i64_or("num_samples", 64) as usize,
                seq_len: dataset.i64_or("seq_len", 64) as usize,
                seed: dataset.i64_or("seed", 0) as u64,
            },
            eval: EvalCfg {
                tasks: eval
                    .get("tasks")
                    .and_then(Yaml::as_seq)
                    .map(|s| {
                        s.iter()
                            .filter_map(Yaml::as_str)
                            .map(String::from)
                            .collect()
                    })
                    .unwrap_or_else(|| vec!["perplexity".to_string()]),
                enabled: eval.bool_or("enabled", true),
            },
            serve: ServeCfg {
                policy: AdmissionPolicy::parse(&serve.str_or("policy", "continuous"))?,
                max_in_flight: non_negative(
                    serve.i64_or("max_in_flight", 8),
                    "serve.max_in_flight",
                )?,
                kv_budget_bytes: non_negative(
                    serve.i64_or("kv_budget_bytes", 0),
                    "serve.kv_budget_bytes",
                )?,
                workers: non_negative(serve.i64_or("workers", 1), "serve.workers")?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        match self.compression.method.as_str() {
            "quantization" | "spec_decode" | "sparse_attn" | "token_prune" => {}
            other => bail!("unknown compression method `{other}`"),
        }
        if !(1..=16).contains(&self.compression.bits) {
            bail!("bits must be in 1..=16, got {}", self.compression.bits);
        }
        if self.compression.ratio <= 0.0 || self.compression.ratio > 1.0 {
            bail!("ratio must be in (0, 1], got {}", self.compression.ratio);
        }
        if self.dataset.seq_len == 0 || self.dataset.num_samples == 0 {
            bail!("dataset must be non-empty");
        }
        if self.serve.max_in_flight == 0 {
            bail!("serve.max_in_flight must be >= 1");
        }
        if self.serve.workers == 0 {
            bail!("serve.workers must be >= 1 (scheduler worker count)");
        }
        if self.serve.kv_budget_bytes > 0 && self.serve.kv_budget_bytes < self.serve.workers {
            bail!(
                "serve.kv_budget_bytes = {} splits to zero across {} workers; \
                 raise the budget, reduce workers, or set 0 for unlimited",
                self.serve.kv_budget_bytes,
                self.serve.workers
            );
        }
        Ok(())
    }
}

/// Reject negative config values instead of letting `as usize` wrap them
/// into huge limits that silently disable the knob they configure.
fn non_negative(v: i64, name: &str) -> Result<usize> {
    if v < 0 {
        bail!("{name} must be >= 0, got {v}");
    }
    Ok(v as usize)
}

fn default_algo(method: &str) -> &'static str {
    match method {
        "quantization" => "fp8_dynamic",
        "spec_decode" => "eagle3",
        "sparse_attn" => "stem",
        "token_prune" => "idpruner",
        _ => "none",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FULL: &str = r#"
global:
  save_path: ./out
  deploy_backend: vllm
  seed: 7
model:
  name: tiny-target
  artifacts_dir: artifacts
  dtype: fp32
compression:
  method: quantization
  quantization:
    algo: leptoquant
    bits: 8
    group_size: 64
    alpha_grid: [0.0, 0.001]
dataset:
  kind: synthetic
  num_samples: 32
  seq_len: 48
eval:
  enabled: true
  tasks:
    - perplexity
    - copy
serve:
  policy: static
  max_in_flight: 4
  kv_budget_bytes: 65536
  workers: 2
"#;

    #[test]
    fn full_roundtrip() {
        let c = SlimConfig::from_str(FULL).unwrap();
        assert_eq!(c.global.seed, 7);
        assert_eq!(c.compression.algo, "leptoquant");
        assert_eq!(c.compression.group_size, 64);
        assert_eq!(c.compression.alpha_grid, vec![0.0, 0.001]);
        assert_eq!(c.dataset.seq_len, 48);
        assert_eq!(c.eval.tasks, vec!["perplexity", "copy"]);
        assert_eq!(c.serve.policy, AdmissionPolicy::Static);
        assert_eq!(c.serve.max_in_flight, 4);
        assert_eq!(c.serve.kv_budget_bytes, 65536);
        assert_eq!(c.serve.workers, 2);
    }

    #[test]
    fn defaults_fill_in() {
        let c = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: sparse_attn\n",
        )
        .unwrap();
        assert_eq!(c.compression.algo, "stem");
        assert_eq!(c.dataset.num_samples, 64);
        assert!(c.eval.enabled);
        assert_eq!(c.serve.policy, AdmissionPolicy::Continuous);
        assert_eq!(c.serve.max_in_flight, 8);
        assert_eq!(c.serve.kv_budget_bytes, 0);
        assert_eq!(c.serve.workers, 1, "single worker unless configured");
    }

    #[test]
    fn rejects_unknown_serve_policy() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\nserve:\n  policy: psychic\n",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_negative_serve_values() {
        for field in ["max_in_flight", "kv_budget_bytes", "workers"] {
            let r = SlimConfig::from_str(&format!(
                "model:\n  name: m\ncompression:\n  method: quantization\nserve:\n  {field}: -1\n",
            ));
            assert!(r.is_err(), "negative {field} must not wrap to usize::MAX");
        }
    }

    // zero-worker and budget-splits-to-zero rejections are covered at the
    // integration level in tests/test_configs.rs (which also exercises the
    // executor-aware ensure_requests_fit guard)

    #[test]
    fn rejects_unknown_method() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: teleport\n",
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bad_bits() {
        let r = SlimConfig::from_str(
            "model:\n  name: m\ncompression:\n  method: quantization\n  quantization:\n    bits: 99\n",
        );
        assert!(r.is_err());
    }

    #[test]
    fn missing_model_errors() {
        assert!(SlimConfig::from_str("compression:\n  method: quantization\n").is_err());
    }
}
