//! Minimal YAML-subset parser.
//!
//! Supported: nested block maps, block sequences (`- item`), scalars
//! (string / int / float / bool / null), inline comments, quoted strings,
//! and flow sequences of scalars (`[a, b, c]`). This covers every config
//! in configs/ and the paper's published examples. Anchors, multi-line
//! scalars, and flow maps are intentionally out of scope.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Yaml {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Seq(Vec<Yaml>),
    Map(BTreeMap<String, Yaml>),
}

#[derive(Debug)]
pub enum YamlError {
    Parse(usize, String),
}

impl fmt::Display for YamlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            YamlError::Parse(line, msg) => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for YamlError {}

impl Yaml {
    pub fn get(&self, key: &str) -> Option<&Yaml> {
        match self {
            Yaml::Map(m) => m.get(key),
            _ => None,
        }
    }

    /// Dotted-path lookup: `cfg.path("compression.quantization.bits")`.
    pub fn path(&self, dotted: &str) -> Option<&Yaml> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Yaml::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Yaml::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Yaml::Float(f) => Some(*f),
            Yaml::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Yaml::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_seq(&self) -> Option<&[Yaml]> {
        match self {
            Yaml::Seq(s) => Some(s),
            _ => None,
        }
    }

    /// Typed getters with defaults — the schema layer leans on these.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Yaml::as_str).unwrap_or(default).to_string()
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(Yaml::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Yaml::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Yaml::as_bool).unwrap_or(default)
    }
}

impl fmt::Display for Yaml {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Yaml::Null => write!(f, "null"),
            Yaml::Bool(b) => write!(f, "{b}"),
            Yaml::Int(i) => write!(f, "{i}"),
            Yaml::Float(x) => write!(f, "{x}"),
            Yaml::Str(s) => write!(f, "{s}"),
            Yaml::Seq(s) => {
                write!(f, "[")?;
                for (i, v) in s.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Yaml::Map(m) => write!(f, "{{{} keys}}", m.len()),
        }
    }
}

fn parse_scalar(s: &str) -> Yaml {
    let t = s.trim();
    if t.is_empty() || t == "~" || t == "null" {
        return Yaml::Null;
    }
    if (t.starts_with('"') && t.ends_with('"') && t.len() >= 2)
        || (t.starts_with('\'') && t.ends_with('\'') && t.len() >= 2)
    {
        return Yaml::Str(t[1..t.len() - 1].to_string());
    }
    match t {
        "true" | "True" => return Yaml::Bool(true),
        "false" | "False" => return Yaml::Bool(false),
        _ => {}
    }
    if let Ok(i) = t.parse::<i64>() {
        return Yaml::Int(i);
    }
    if let Ok(x) = t.parse::<f64>() {
        return Yaml::Float(x);
    }
    if t.starts_with('[') && t.ends_with(']') {
        let inner = &t[1..t.len() - 1];
        if inner.trim().is_empty() {
            return Yaml::Seq(vec![]);
        }
        return Yaml::Seq(inner.split(',').map(parse_scalar).collect());
    }
    Yaml::Str(t.to_string())
}

/// Strip comments outside of quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_s = false;
    let mut in_d = false;
    for (i, c) in line.char_indices() {
        match c {
            '\'' if !in_d => in_s = !in_s,
            '"' if !in_s => in_d = !in_d,
            '#' if !in_s && !in_d => return &line[..i],
            _ => {}
        }
    }
    line
}

struct Line {
    indent: usize,
    content: String,
    num: usize,
}

fn lex(src: &str) -> Vec<Line> {
    let mut out = Vec::new();
    for (num, raw) in src.lines().enumerate() {
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        if trimmed.trim() == "---" {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        out.push(Line {
            indent,
            content: trimmed.trim_start().to_string(),
            num: num + 1,
        });
    }
    out
}

pub fn parse(src: &str) -> Result<Yaml, YamlError> {
    let lines = lex(src);
    if lines.is_empty() {
        return Ok(Yaml::Map(BTreeMap::new()));
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, lines[0].indent)?;
    if pos < lines.len() {
        return Err(YamlError::Parse(
            lines[pos].num,
            format!("unexpected trailing content: {}", lines[pos].content),
        ));
    }
    Ok(v)
}

fn parse_block(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    if *pos >= lines.len() {
        return Ok(Yaml::Null);
    }
    if lines[*pos].content.starts_with("- ") || lines[*pos].content == "-" {
        parse_seq(lines, pos, indent)
    } else {
        parse_map(lines, pos, indent)
    }
}

fn parse_seq(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError::Parse(line.num, "bad sequence indent".into()));
        }
        if !(line.content.starts_with("- ") || line.content == "-") {
            break;
        }
        let rest = line.content[1..].trim().to_string();
        *pos += 1;
        if rest.is_empty() {
            // nested block under "-"
            if *pos < lines.len() && lines[*pos].indent > indent {
                let child_indent = lines[*pos].indent;
                items.push(parse_block(lines, pos, child_indent)?);
            } else {
                items.push(Yaml::Null);
            }
        } else if rest.contains(':') && !rest.starts_with('[') {
            // inline "key: value" — start of a map item; re-parse with
            // the remainder as its first line, children indented deeper.
            let mut map = BTreeMap::new();
            let (k, v) = split_kv(&rest, line.num)?;
            insert_kv(&mut map, k, v, lines, pos, indent + 2)?;
            // additional keys of the same item are indented by 2 from "-"
            while *pos < lines.len() && lines[*pos].indent == indent + 2 {
                let l2 = &lines[*pos];
                if l2.content.starts_with("- ") {
                    break;
                }
                let (k2, v2) = split_kv(&l2.content, l2.num)?;
                *pos += 1;
                insert_kv(&mut map, k2, v2, lines, pos, indent + 4)?;
            }
            items.push(Yaml::Map(map));
        } else {
            items.push(parse_scalar(&rest));
        }
    }
    Ok(Yaml::Seq(items))
}

fn split_kv(content: &str, num: usize) -> Result<(String, String), YamlError> {
    let idx = content
        .find(':')
        .ok_or_else(|| YamlError::Parse(num, format!("expected key: value in `{content}`")))?;
    Ok((
        content[..idx].trim().to_string(),
        content[idx + 1..].trim().to_string(),
    ))
}

fn insert_kv(
    map: &mut BTreeMap<String, Yaml>,
    key: String,
    val: String,
    lines: &[Line],
    pos: &mut usize,
    min_child_indent: usize,
) -> Result<(), YamlError> {
    if val.is_empty() {
        if *pos < lines.len() && lines[*pos].indent >= min_child_indent {
            let child_indent = lines[*pos].indent;
            let child = parse_block(lines, pos, child_indent)?;
            map.insert(key, child);
        } else {
            map.insert(key, Yaml::Null);
        }
    } else {
        map.insert(key, parse_scalar(&val));
    }
    Ok(())
}

fn parse_map(lines: &[Line], pos: &mut usize, indent: usize) -> Result<Yaml, YamlError> {
    let mut map = BTreeMap::new();
    while *pos < lines.len() {
        let line = &lines[*pos];
        if line.indent < indent {
            break;
        }
        if line.indent > indent {
            return Err(YamlError::Parse(line.num, "bad map indent".into()));
        }
        if line.content.starts_with("- ") {
            break;
        }
        let (k, v) = split_kv(&line.content, line.num)?;
        *pos += 1;
        insert_kv(&mut map, k, v, lines, pos, indent + 1)?;
    }
    Ok(Yaml::Map(map))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# AngelSlim-style config
global:
  save_path: ./out
  deploy_backend: vllm
model:
  name: tiny-target     # trailing comment
  dtype: "fp32"
compression:
  method: quantization
  quantization:
    algo: leptoquant
    bits: 8
    alpha_grid: [0.0, 0.0005, 0.001]
    use_smoothing: false
dataset:
  kind: synthetic
  num_samples: 128
"#;

    #[test]
    fn parses_nested_maps() {
        let y = parse(SAMPLE).unwrap();
        assert_eq!(y.path("global.save_path").unwrap().as_str(), Some("./out"));
        assert_eq!(y.path("model.dtype").unwrap().as_str(), Some("fp32"));
        assert_eq!(
            y.path("compression.quantization.bits").unwrap().as_i64(),
            Some(8)
        );
        assert_eq!(
            y.path("compression.quantization.use_smoothing")
                .unwrap()
                .as_bool(),
            Some(false)
        );
        assert_eq!(y.path("dataset.num_samples").unwrap().as_i64(), Some(128));
    }

    #[test]
    fn parses_flow_seq() {
        let y = parse(SAMPLE).unwrap();
        let grid = y
            .path("compression.quantization.alpha_grid")
            .unwrap()
            .as_seq()
            .unwrap();
        assert_eq!(grid.len(), 3);
        assert_eq!(grid[1].as_f64(), Some(0.0005));
        assert_eq!(grid[0].as_f64(), Some(0.0));
    }

    #[test]
    fn parses_block_seq() {
        let y = parse("methods:\n  - fastv\n  - idpruner\n  - samp\n").unwrap();
        let s = y.get("methods").unwrap().as_seq().unwrap();
        assert_eq!(s.len(), 3);
        assert_eq!(s[2].as_str(), Some("samp"));
    }

    #[test]
    fn parses_seq_of_maps() {
        let src = "jobs:\n  - name: a\n    bits: 4\n  - name: b\n    bits: 8\n";
        let y = parse(src).unwrap();
        let s = y.get("jobs").unwrap().as_seq().unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(s[1].get("bits").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn scalars_and_defaults() {
        let y = parse("a: 1\nb: 2.5\nc: yes_string\nd: true\ne:\n").unwrap();
        assert_eq!(y.i64_or("a", 0), 1);
        assert_eq!(y.f64_or("b", 0.0), 2.5);
        assert_eq!(y.str_or("c", ""), "yes_string");
        assert!(y.bool_or("d", false));
        assert_eq!(y.get("e"), Some(&Yaml::Null));
        assert_eq!(y.i64_or("missing", 42), 42);
    }

    #[test]
    fn quoted_hash_not_comment() {
        let y = parse("k: \"a # b\"\n").unwrap();
        assert_eq!(y.get("k").unwrap().as_str(), Some("a # b"));
    }

    #[test]
    fn empty_doc() {
        assert_eq!(parse("").unwrap(), Yaml::Map(BTreeMap::new()));
        assert_eq!(parse("# just a comment\n").unwrap(), Yaml::Map(BTreeMap::new()));
    }

    #[test]
    fn rejects_bad_indent() {
        assert!(parse("a:\n  b: 1\n c: 2\n").is_err());
    }
}
