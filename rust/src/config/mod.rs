//! Config subsystem — the entry stage of the paper's pipeline (Fig. 6):
//! "AngelSlim starts by parsing a YAML configuration file to load all
//! essential parameters for the compression task ... global settings, model
//! information, compression algorithm specifications, and dataset
//! configurations."
//!
//! serde/serde_yaml are unavailable offline, so `yaml` is a hand-rolled
//! parser for the YAML subset these configs need (nested maps, sequences,
//! scalars, comments), and `schema` maps the generic tree onto typed config
//! structs with defaulting + validation.

pub mod json;
pub mod schema;
pub mod yaml;

pub use json::Json;
pub use schema::{
    CompressionCfg, DatasetCfg, EvalCfg, GlobalCfg, ModelCfg, SlimConfig, StageCfg,
};
pub use yaml::{parse, Yaml};
