//! Minimal JSON parser — reads artifacts/meta.json (layout contract with
//! the python AOT build). Full JSON value model, no serde available.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError(usize, String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.0, self.1)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let b = src.as_bytes();
        let mut pos = 0;
        let v = parse_value(b, &mut pos)?;
        skip_ws(b, &mut pos);
        if pos != b.len() {
            return Err(JsonError(pos, "trailing content".into()));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err(JsonError(*pos, "unexpected end".into()));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json, JsonError> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        Err(JsonError(*pos, format!("expected {word}")))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| JsonError(start, "bad number".into()))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    break;
                }
                let c = b[*pos];
                *pos += 1;
                match c {
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'u' => {
                        let hex = std::str::from_utf8(&b[*pos..*pos + 4])
                            .map_err(|_| JsonError(*pos, "bad \\u".into()))?;
                        let cp = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError(*pos, "bad \\u".into()))?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    other => out.push(other as char),
                }
            }
            c => {
                // fast path: copy a run of plain bytes
                let start = *pos;
                while *pos < b.len() && b[*pos] != b'"' && b[*pos] != b'\\' {
                    *pos += 1;
                }
                out.push_str(std::str::from_utf8(&b[start..*pos]).map_err(|_| {
                    JsonError(start, format!("bad utf8 near {c}"))
                })?);
            }
        }
    }
    Err(JsonError(*pos, "unterminated string".into()))
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // [
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            _ => return Err(JsonError(*pos, "expected , or ]".into())),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // {
    let mut out = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(JsonError(*pos, "expected :".into()));
        }
        *pos += 1;
        out.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            _ => return Err(JsonError(*pos, "expected , or }".into())),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_meta_shape() {
        let src = r#"{"seq_t": 64, "target": {"vocab": 256, "d_model": 128},
                      "layout": [{"name": "embed", "shape": [256, 128],
                                  "offset": 0, "len": 32768}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("seq_t").unwrap().as_usize(), Some(64));
        assert_eq!(
            j.get("target").unwrap().get("d_model").unwrap().as_usize(),
            Some(128)
        );
        let l0 = j.get("layout").unwrap().idx(0).unwrap();
        assert_eq!(l0.get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(
            l0.get("shape").unwrap().as_arr().unwrap()[1].as_usize(),
            Some(128)
        );
    }

    #[test]
    fn parses_escapes_and_nested() {
        let j = Json::parse(r#"{"a": "x\ny\"z", "b": [1, 2.5, -3e2, true, null]}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_str(), Some("x\ny\"z"));
        let b = j.get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[2].as_f64(), Some(-300.0));
        assert_eq!(b[3], Json::Bool(true));
        assert_eq!(b[4], Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
