//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored shim
//! implements exactly the subset the workspace uses: `Error`, `Result`,
//! the `Context` extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error chains render the same
//! way callers expect: `{}` prints the outermost message, `{:#}` prints
//! the full colon-separated chain, and `{:?}` prints a "Caused by" list.
//!
//! The API is call-compatible with real `anyhow` for everything in this
//! repo, so switching back to the crates.io crate is a Cargo.toml-only
//! change.

use std::any::Any;
use std::fmt;

/// A context-chained error value. Like `anyhow::Error`, this type does
/// NOT implement `std::error::Error` itself — that is what lets the
/// blanket `From<E: std::error::Error>` conversion coexist with the
/// reflexive `From<Error>` impl that `?` relies on.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
    /// the original typed error, kept so `downcast_ref` works through
    /// `?` conversions and `.context(..)` wrapping like real `anyhow`
    payload: Option<Box<dyn Any + Send + Sync>>,
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct an error from a typed `std::error::Error`, keeping the
    /// value itself recoverable through [`Error::downcast_ref`].
    pub fn new<E>(e: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let msg = e.to_string();
        let source = e.source().map(|s| Box::new(Error::from_std(s)));
        Error { msg, source, payload: Some(Box::new(e)) }
    }

    /// Construct an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None, payload: None }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), source: Some(Box::new(self)), payload: None }
    }

    /// The typed error this chain was built from, if any node still
    /// carries one of type `E` (outermost match wins, like `anyhow`).
    pub fn downcast_ref<E: 'static>(&self) -> Option<&E> {
        let mut cur = Some(self);
        while let Some(e) = cur {
            if let Some(p) = e.payload.as_deref().and_then(|p| p.downcast_ref::<E>()) {
                return Some(p);
            }
            cur = e.source.as_deref();
        }
        None
    }

    fn from_std(e: &(dyn std::error::Error + 'static)) -> Self {
        Error {
            msg: e.to_string(),
            source: e.source().map(|s| Box::new(Error::from_std(s))),
            payload: None,
        }
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: Some(self) }
    }

    /// The innermost (root) error in the chain.
    pub fn root_cause(&self) -> &Error {
        let mut cur = self;
        while let Some(s) = cur.source.as_deref() {
            cur = s;
        }
        cur
    }
}

/// Iterator over an error's context chain.
pub struct Chain<'a> {
    next: Option<&'a Error>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a Error;

    fn next(&mut self) -> Option<&'a Error> {
        let cur = self.next?;
        self.next = cur.source.as_deref();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if f.alternate() {
            let mut cur = self.source.as_deref();
            while let Some(e) = cur {
                write!(f, ": {}", e.msg)?;
                cur = e.source.as_deref();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if self.source.is_some() {
            write!(f, "\n\nCaused by:")?;
            let mut cur = self.source.as_deref();
            let mut i = 0usize;
            while let Some(e) = cur {
                write!(f, "\n    {i}: {}", e.msg)?;
                cur = e.source.as_deref();
                i += 1;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error::new(e)
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Alias of [`anyhow!`] kept for API compatibility.
#[macro_export]
macro_rules! format_err {
    ($($arg:tt)+) => {
        $crate::anyhow!($($arg)+)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug)]
    struct Leaf;

    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "file missing")
        }
    }

    impl std::error::Error for Leaf {}

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(Leaf)?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "file missing");
        // io::Error converts too (exact chain shape is io-internal)
        fn io_inner() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))?;
            Ok(())
        }
        assert_eq!(io_inner().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<(), Leaf> = Err(Leaf);
        let e = e.context("reading weights").unwrap_err();
        assert_eq!(format!("{e}"), "reading weights");
        assert_eq!(format!("{e:#}"), "reading weights: file missing");
        assert_eq!(e.chain().count(), 2);
        assert_eq!(e.root_cause().to_string(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn bail_and_ensure() {
        fn b(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 3);
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(b(2).unwrap(), 2);
        assert_eq!(b(11).unwrap_err().to_string(), "x too big: 11");
        assert!(b(3).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(b(5).unwrap_err().to_string(), "five is right out");
    }

    #[test]
    fn downcast_ref_recovers_typed_errors_through_context() {
        let e = Error::new(Leaf);
        assert!(e.downcast_ref::<Leaf>().is_some());
        // `?` conversion keeps the payload
        fn inner() -> Result<()> {
            Err(Leaf)?;
            Ok(())
        }
        let e = inner().unwrap_err().context("while loading");
        assert!(e.downcast_ref::<Leaf>().is_some(), "payload survives context");
        assert!(e.downcast_ref::<std::io::Error>().is_none());
        // message-only errors carry no payload
        assert!(Error::msg("plain").downcast_ref::<Leaf>().is_none());
    }

    #[test]
    fn debug_renders_cause_list() {
        let e = Error::msg("root").context("mid").context("top");
        let d = format!("{e:?}");
        assert!(d.starts_with("top"));
        assert!(d.contains("Caused by:"));
        assert!(d.contains("1: root"));
    }
}
