//! Offline stub of the `xla` crate (xla_extension / PJRT bindings).
//!
//! The hermetic build has no registry or system `xla_extension`, but the
//! `pjrt` feature still has to *compile* so CI can type-check the real
//! executor (`runtime/executor.rs`) instead of letting it bit-rot behind
//! an unbuildable feature flag. This crate mirrors exactly the subset of
//! the `xla` 0.5.x API that executor uses; every operation that would
//! touch PJRT returns an explicit [`Error`] at runtime — starting with
//! [`PjRtClient::cpu`], so nothing downstream can silently "succeed".
//!
//! Swapping in the real bindings is a Cargo.toml-only change: point the
//! `xla` path dependency at a checkout of the genuine crate and rebuild
//! with `--features pjrt`.

use std::fmt;

/// Error type matching the shape the real bindings expose: implements
/// `std::error::Error`, so `?` and `.context(..)` convert it into the
/// workspace's `anyhow::Error` exactly like the genuine crate's errors.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(op: impl fmt::Display) -> Self {
        Error {
            msg: format!(
                "xla stub: {op} is unavailable (this build links the vendored \
                 compile-only stand-in; point Cargo.toml's `xla` path at the real \
                 xla_extension bindings to execute artifacts)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can be built from or read into.
pub trait NativeType: Copy + 'static {}

impl NativeType for u8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host-side tensor value. The stub carries no storage: values only ever
/// exist on the far side of a compiled executable, and no executable can
/// be built without a client, whose constructor fails first.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (the AOT interchange format is HLO *text*).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<std::path::Path>>(path: P) -> Result<HloModuleProto> {
        Err(Error::unavailable(format!(
            "HloModuleProto::from_text_file({})",
            path.as_ref().display()
        )))
    }
}

/// Computation handle wrapping a parsed module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by an execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle; the stub's constructor is the loud front door.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub client must not succeed");
        let msg = err.to_string();
        assert!(msg.contains("xla stub"), "{msg}");
        assert!(msg.contains("PjRtClient::cpu"), "{msg}");
    }

    #[test]
    fn literal_ops_fail_rather_than_fabricate_data() {
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[2]).is_err());
        assert!(Literal::vec1(&[0f32]).to_vec::<f32>().is_err());
        assert!(Literal::vec1(&[0f32]).to_tuple1().is_err());
        assert!(HloModuleProto::from_text_file("missing.hlo.txt").is_err());
    }

    #[test]
    fn error_converts_like_a_std_error() {
        fn chain() -> std::result::Result<(), Box<dyn std::error::Error>> {
            PjRtClient::cpu()?;
            Ok(())
        }
        assert!(chain().is_err());
    }
}
