//! END-TO-END DRIVER: serve batched requests through the PJRT runtime with
//! Eagle3-style speculative decoding and report latency/throughput —
//! proving all three layers compose (Pallas-lowered JAX models -> HLO text
//! artifacts -> Rust coordinator serving loop). Recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_spec_decode

use angelslim::data::RequestGen;
use angelslim::runtime::ArtifactRegistry;
use angelslim::server::ServingEngine;
use angelslim::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let mut reg = ArtifactRegistry::open("artifacts")?;
    println!("PJRT platform: {}", reg.rt.platform());
    let target = reg.model("model_target_fp32_b1")?;
    let draft = reg.model("model_draft_fp32_b1")?;
    let corpus = std::fs::read("artifacts/eval_corpus.bin")?;

    let n_requests = 24;
    let make_requests = || {
        let mut gen = RequestGen::new(corpus.clone(), 42);
        gen.take(n_requests)
    };

    println!("serving {n_requests} requests, vanilla decoding...");
    let vanilla = ServingEngine::serve::<
        std::sync::Arc<angelslim::runtime::ModelExecutable>,
        _,
    >(make_requests(), &target, None, 0)?;

    println!("serving {n_requests} requests, Eagle3-style speculative (gamma=3)...");
    let spec = ServingEngine::serve(make_requests(), &target, Some((&draft, 3)), 0)?;

    // correctness: greedy speculative decoding must match vanilla outputs
    let mut identical = 0;
    for (a, b) in vanilla.completed.iter().zip(&spec.completed) {
        if a.output == b.output {
            identical += 1;
        }
    }

    let mut t = Table::new(
        "end-to-end serving: vanilla vs Eagle3-style speculative (PJRT CPU)",
        &["mode", "TPS", "AL", "TTFT p50 ms", "lat p50 ms", "lat p90 ms"],
    );
    for (name, r) in [("Vanilla", &vanilla), ("Eagle3", &spec)] {
        t.row_strs(&[
            name,
            &f2(r.tps()),
            &f2(r.mean_al),
            &f2(r.ttft_summary().p50),
            &f2(r.latency_summary().p50),
            &f2(r.latency_summary().p90),
        ]);
    }
    t.print();
    println!(
        "speedup {:.2}x | outputs identical {identical}/{n_requests}",
        spec.tps() / vanilla.tps()
    );
    assert_eq!(identical, n_requests, "speculative decoding must not change outputs");
    println!("serve_spec_decode OK");
    Ok(())
}
