//! Quickstart: the paper's "one-click" flow — parse a YAML config, run the
//! Compress Engine, read the report.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (builds the AOT models + weights).

use angelslim::config::SlimConfig;
use angelslim::coordinator::CompressEngine;

const CONFIG: &str = r#"
global:
  save_path: ./output/quickstart
  seed: 0
model:
  name: tiny-target
  artifacts_dir: artifacts
compression:
  method: quantization
  quantization:
    algo: int4
    bits: 4
    group_size: 32
dataset:
  kind: artifact
  num_samples: 8
  seq_len: 48
"#;

fn main() -> anyhow::Result<()> {
    let cfg = SlimConfig::from_str(CONFIG)?;
    println!(
        "job: {} / {} on model {}",
        cfg.compression.method, cfg.compression.algo, cfg.model.name
    );
    let report = CompressEngine::new(cfg)?.run()?;
    for stage in &report.stages {
        println!(
            "[{}] NLL before {:.4} -> after {:.4} at {:.2} effective bits/weight",
            stage.pass, stage.metric_before, stage.metric_after, stage.compression
        );
        for note in &stage.notes {
            println!("note: {note}");
        }
    }
    println!("overall size ratio {:.4}", report.overall_size_ratio());
    println!("quickstart OK");
    Ok(())
}
