//! Multimodal token reduction: the visual pruner sweep (IDPruner + 8
//! baselines) on synthetic scenes and the audio reducer sweep (Samp + 5
//! baselines) on synthetic speech streams — the paper's §4.2 framework.
//!
//!     cargo run --release --example multimodal_prune

use angelslim::data::{AudioSceneGen, VisionSceneGen};
use angelslim::eval::{asr, eval_pruner_accuracy, eval_wer, vqa};
use angelslim::token_prune::{audio::all_audio_reducers, visual::all_visual_pruners};
use angelslim::util::table::{f2, pct, Table};

fn main() -> anyhow::Result<()> {
    // visual
    let gen = VisionSceneGen::new(96, 24, 6, 0);
    let n = 60;
    let base = vqa::baseline_accuracy(&gen, n);
    let mut t = Table::new(
        &format!("visual pruning (baseline accuracy {})", pct(base)),
        &["method", "retain 25%", "retain 10%"],
    );
    for p in all_visual_pruners() {
        let a25 = eval_pruner_accuracy(&gen, p.as_ref(), 0.25, n);
        let a10 = eval_pruner_accuracy(&gen, p.as_ref(), 0.10, n);
        t.row_strs(&[p.name(), &pct(a25), &pct(a10)]);
    }
    t.print();

    // audio
    let agen = AudioSceneGen::new(16, 40, 0.3, 0);
    let scenes = 20;
    let frames = 150;
    let base_wer = asr::baseline_wer(&agen, scenes, frames);
    let mut t = Table::new(
        &format!("audio reduction WER%% (full-token baseline {:.2})", base_wer),
        &["method", "retain 40%", "retain 55%"],
    );
    for r in all_audio_reducers() {
        let w60 = eval_wer(&agen, r.as_ref(), 0.4, scenes, frames);
        let w70 = eval_wer(&agen, r.as_ref(), 0.55, scenes, frames);
        t.row_strs(&[r.name(), &f2(w60), &f2(w70)]);
    }
    t.print();
    println!("multimodal_prune OK");
    Ok(())
}
