//! Long-context sparse prefill: estimate patterns from the model's own
//! Q/K, execute through BOTH consumers of the block-mask metadata — the
//! pure-Rust masked forward and the Pallas block-sparse attention kernel
//! artifact (PJRT) — and report retrieval accuracy + analytic speedup.
//!
//!     cargo run --release --example longcontext_prefill

use angelslim::eval::eval_sparse_accuracy;
use angelslim::models::{Transformer, WeightStore};
use angelslim::runtime::{executor::AttnExecutable, PjrtRuntime};
use angelslim::sparse_attn::{attn_flops, SparseAlgo};
use angelslim::util::table::{f2, Table};
use angelslim::util::Rng;

fn main() -> anyhow::Result<()> {
    let ws = WeightStore::load("artifacts")?;
    let model = Transformer::from_store(&ws, "target")?;
    let budget = 0.35;
    let seq = 120;

    let mut t = Table::new(
        &format!("sparse prefill at density budget {budget} (seq {seq})"),
        &["algo", "avg acc", "density", "analytic speedup"],
    );
    for algo in [
        SparseAlgo::Dense,
        SparseAlgo::AShape,
        SparseAlgo::TriShape,
        SparseAlgo::MInference,
        SparseAlgo::XAttention,
        SparseAlgo::FlexPrefill,
        SparseAlgo::Stem,
    ] {
        let row = eval_sparse_accuracy(&model, algo, seq, 6, 16, budget);
        // analytic speedup from one representative mask
        let qkv = model.capture_qk(&vec![1u8; seq]);
        let (q, k, v) = &qkv[0];
        let mask = algo.mask(q, k, v, 16, budget);
        let speedup = attn_flops(seq, q.cols())
            / angelslim::sparse_attn::flops::masked_attn_flops(&mask, q.cols(), 0);
        t.row_strs(&[algo.name(), &f2(row.avg), &f2(row.mean_density), &f2(speedup)]);
    }
    t.print();

    // run the same metadata through the Pallas kernel artifact (T=128)
    let rt = PjrtRuntime::cpu()?;
    let attn = AttnExecutable::new(&rt, "artifacts/sparse_attn.hlo.txt", 128, 4, 32, 8)?;
    let mut rng = Rng::new(0);
    let n = 128 * 4 * 32;
    let q: Vec<f32> = (0..n).map(|_| rng.normal() * 0.3).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.normal() * 0.3).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal() * 0.3).collect();
    let dense_mask = vec![1.0f32; 64];
    let out = attn.run(&q, &k, &v, &dense_mask)?;
    println!(
        "\nPallas block-sparse kernel artifact executed on PJRT: out[0..4] = {:?}",
        &out[..4]
    );
    println!("longcontext_prefill OK");
    Ok(())
}
