//! PTQ pipeline walkthrough: calibrate, quantize with each registered PTQ
//! algorithm (RTN int8/int4, GPTQ, AWQ, fp8, LeptoQuant), compare
//! perplexity and effective bits — the paper's §2.3 framework in one run.
//!
//!     cargo run --release --example ptq_pipeline

use angelslim::config::SlimConfig;
use angelslim::coordinator::CompressEngine;
use angelslim::util::table::{f2, Table};

fn main() -> anyhow::Result<()> {
    let mut t = Table::new(
        "PTQ suite on tiny-target (NLL on held-out stream, lower = better)",
        &["algo", "bits", "NLL before", "NLL after", "delta"],
    );
    for algo in ["int8", "fp8_dynamic", "leptoquant", "int4", "gptq", "awq", "w4a8", "seq2", "ternary"] {
        let src = format!(
            "global:\n  save_path: ./output/ptq\nmodel:\n  name: tiny-target\n  artifacts_dir: artifacts\n\
             compression:\n  method: quantization\n  quantization:\n    algo: {algo}\n\
             dataset:\n  kind: artifact\n  num_samples: 10\n  seq_len: 48\n"
        );
        let report = CompressEngine::new(SlimConfig::from_str(&src)?)?.run()?;
        let stage = &report.stages[0];
        t.row_strs(&[
            algo,
            &f2(stage.compression),
            &f2(stage.metric_before),
            &f2(stage.metric_after),
            &f2(stage.metric_after - stage.metric_before),
        ]);
    }
    t.print();
    println!("ptq_pipeline OK");
    Ok(())
}
