//! Cache-correctness property tests for the incremental-decoding
//! subsystem: `prefill` + `decode_step` must be *logit-exact* (bitwise,
//! not approximately) vs the full `forward` across random prompts, split
//! points, and rollbacks; the speculative decoder must stay
//! output-identical to vanilla decoding while rolling its target cache
//! back on rejection; and the cache's truncation / memory accounting must
//! uphold its invariants.

use angelslim::models::{AttnOverride, KvCache, Transformer};
use angelslim::server::ServingEngine;
use angelslim::spec_decode::{DecodeSession, SessionModel, SpecDecoder, VanillaDecoder};
use angelslim::util::fixtures::{
    fixture_corpus, fixture_draft, fixture_target, fixture_transformer, FixtureSpec,
};
use angelslim::util::Rng;

fn random_prompt(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(256) as u8).collect()
}

/// prefill over the whole prompt returns every logits row bit-identical
/// to the full forward, across random prompts and lengths.
#[test]
fn prefill_is_logit_exact_vs_forward() {
    let mut rng = Rng::new(11);
    for seed in 0..4u64 {
        let m = fixture_target(seed);
        for len in [1usize, 2, 5, 17, 40] {
            let toks = random_prompt(&mut rng, len);
            let full = m.forward(&toks, &AttnOverride::None);
            let mut cache = m.new_cache();
            let rows = m.prefill(&mut cache, &toks);
            assert_eq!(rows.dims(), full.dims());
            assert_eq!(rows.data, full.data, "seed {seed} len {len}");
            assert_eq!(cache.len(), len);
        }
    }
}

/// Every decode_step position matches the corresponding row of a fresh
/// full forward, for arbitrary prefill/decode split points.
#[test]
fn decode_steps_are_logit_exact_at_every_position() {
    let mut rng = Rng::new(23);
    let m = fixture_target(7);
    for split in [1usize, 3, 8] {
        let toks = random_prompt(&mut rng, 20);
        let mut cache = m.new_cache();
        m.prefill(&mut cache, &toks[..split]);
        for i in split..toks.len() {
            let step = m.decode_step(&mut cache, toks[i]);
            let full = m.forward(&toks[..=i], &AttnOverride::None);
            assert_eq!(&step[..], full.row(i), "split {split} pos {i}");
            assert_eq!(cache.len(), i + 1);
        }
    }
}

/// Chained prefills (multi-token extension of a warm cache — the
/// speculative verify pass) match one forward over the concatenation.
#[test]
fn chained_prefills_match_single_forward() {
    let mut rng = Rng::new(31);
    let m = fixture_target(3);
    let a = random_prompt(&mut rng, 9);
    let b = random_prompt(&mut rng, 7);
    let mut all = a.clone();
    all.extend_from_slice(&b);
    let full = m.forward(&all, &AttnOverride::None);
    let mut cache = m.new_cache();
    m.prefill(&mut cache, &a);
    let rows_b = m.prefill(&mut cache, &b);
    for (i, pos) in (a.len()..all.len()).enumerate() {
        assert_eq!(rows_b.row(i), full.row(pos), "extension row {i}");
    }
}

/// Truncating to an accepted prefix and re-extending with a different
/// continuation replays exactly what a cold cache computes — the
/// speculative-rejection rollback path.
#[test]
fn rollback_then_reextend_is_exact() {
    let mut rng = Rng::new(47);
    let m = fixture_target(5);
    let prefix = random_prompt(&mut rng, 10);
    let rejected = random_prompt(&mut rng, 6);
    let accepted = random_prompt(&mut rng, 6);

    let mut cache = m.new_cache();
    m.prefill(&mut cache, &prefix);
    m.prefill(&mut cache, &rejected);
    cache.truncate(prefix.len());
    assert_eq!(cache.len(), prefix.len());
    let rows = m.prefill(&mut cache, &accepted);

    let mut all = prefix.clone();
    all.extend_from_slice(&accepted);
    let full = m.forward(&all, &AttnOverride::None);
    for i in 0..accepted.len() {
        assert_eq!(rows.row(i), full.row(prefix.len() + i), "replayed row {i}");
    }
}

/// The KvSession wrapper (what the decoders drive) agrees with seq_logits
/// and reports its cache length through the trait surface.
#[test]
fn kv_session_extend_matches_seq_logits() {
    use angelslim::spec_decode::LogitsModel;
    let m = fixture_target(9);
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 256, 2);
    let toks = &corpus[..24];
    let reference = m.seq_logits(toks).unwrap();
    let mut sess = m.new_session();
    let mut got: Vec<Vec<f32>> = sess.extend(&m, &toks[..10]).unwrap();
    for &t in &toks[10..] {
        got.extend(sess.extend(&m, &[t]).unwrap());
    }
    assert_eq!(sess.len(), toks.len());
    assert_eq!(got, reference);
    sess.rollback(4);
    assert_eq!(sess.len(), 4);
}

/// Memory accounting: bytes grow linearly with cached tokens, shrink on
/// truncation, and capacity_bytes is invariant.
#[test]
fn cache_memory_accounting_invariants() {
    let m = fixture_target(0);
    let mut cache = m.new_cache();
    let per_token = m.cfg.n_layers * 2 * m.cfg.d_model * std::mem::size_of::<f32>();
    assert_eq!(cache.bytes(), 0);
    assert_eq!(cache.capacity_bytes(), per_token * m.cfg.max_t);
    m.prefill(&mut cache, &[1, 2, 3, 4, 5]);
    assert_eq!(cache.bytes(), 5 * per_token);
    let cap_before = cache.capacity_bytes();
    cache.truncate(2);
    assert_eq!(cache.bytes(), 2 * per_token);
    assert_eq!(cache.capacity_bytes(), cap_before);
    cache.clear();
    assert_eq!(cache.bytes(), 0);
    assert_eq!(cache.capacity(), m.cfg.max_t);
}

#[test]
#[should_panic(expected = "max_t")]
fn decode_beyond_capacity_panics() {
    let m = fixture_target(0);
    let mut cache = m.new_cache();
    for _ in 0..m.cfg.max_t + 1 {
        m.decode_step(&mut cache, 1);
    }
}

/// A standalone KvCache rejects models it wasn't sized for.
#[test]
#[should_panic(expected = "layer mismatch")]
fn mismatched_cache_panics() {
    let m = fixture_target(0);
    let other = fixture_draft(0); // 1 layer vs 2
    let mut cache = KvCache::new(&other.cfg);
    m.prefill(&mut cache, &[1, 2, 3]);
}

/// Cached speculative decoding (KV sessions + rollback on rejection) is
/// output-identical to cached vanilla decoding, for drafts that agree
/// (high acceptance) and drafts that encode a different rule (constant
/// rejection, so the rollback path is exercised hard).
#[test]
fn spec_decode_with_cache_rollback_is_output_identical() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 4_096, 13);
    let target = fixture_target(6);
    let aligned = fixture_draft(6);
    let wrong = fixture_transformer(&FixtureSpec { shift: 11, seed: 99, ..FixtureSpec::default() });

    for start in [0usize, 50, 300] {
        let prompt = &corpus[start..start + 8];
        for gamma in [1usize, 3, 4] {
            let mut rng = Rng::new(start as u64);
            let (vseq, vstats) = VanillaDecoder::new(&target)
                .generate(prompt, 24, &mut rng)
                .unwrap();
            let (aseq, astats) = SpecDecoder::new(&aligned, &target, gamma)
                .generate(prompt, 24, &mut rng)
                .unwrap();
            assert_eq!(vseq, aseq, "aligned draft start {start} gamma {gamma}");
            assert_eq!(vstats.generated, astats.generated);
            let (wseq, wstats) = SpecDecoder::new(&wrong, &target, gamma)
                .generate(prompt, 24, &mut rng)
                .unwrap();
            assert_eq!(vseq, wseq, "wrong draft start {start} gamma {gamma}");
            assert!(wstats.steps >= astats.steps, "rejections cannot speed decoding up");
        }
    }
}

/// Batched serving over KV sessions produces the same outputs as
/// per-request sequential serving.
#[test]
fn serve_batched_kv_matches_sequential() {
    use angelslim::data::TokenRequest;
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 21);
    let target = fixture_target(4);
    let make = || -> Vec<TokenRequest> {
        (0..6)
            .map(|i| TokenRequest {
                id: i as u64,
                prompt: corpus[i * 31..i * 31 + 8].to_vec(),
                max_new_tokens: 12,
                arrival_ms: i as f64,
                deadline_ms: None,
                class: Default::default(),
            })
            .collect()
    };
    let sequential = ServingEngine::serve::<Transformer, _>(make(), &target, None, 0).unwrap();
    let batched = ServingEngine::serve_batched(make(), &target, 3).unwrap();
    assert_eq!(batched.completed.len(), 6);
    for (a, b) in sequential.completed.iter().zip(&batched.completed) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output, b.output, "request {}", a.id);
        assert_eq!(a.generated, 12);
    }
}
