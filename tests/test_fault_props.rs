//! Chaos property tests for the fault-tolerant serving pool: under
//! deterministic injection (step errors, poisoned logits, stalls, worker
//! crashes) every submitted request must still reach exactly one terminal
//! outcome, completed outputs must stay bit-identical to sequential
//! decoding (containment and retry never corrupt a decode), the KV
//! admission budget must hold with faulted reservations released, and the
//! same seed must reproduce the same report. The per-(request, attempt,
//! round) keyed draws in `server::faults` make the injected fault set
//! independent of worker count, which the cross-worker matrix pins down.

use angelslim::data::TokenRequest;
use angelslim::models::Transformer;
use angelslim::server::{FaultPlan, RequestOutcome, ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_target, FixtureSpec};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, assert_terminal_outcomes, check,
    fixture_requests, projected_greedy_bytes as projected_greedy,
};
use angelslim::util::Rng;

fn run(
    reqs: Vec<TokenRequest>,
    target: &Transformer,
    cfg: &ServeCfg,
) -> ServeReport {
    ServingEngine::serve_scheduled::<Transformer, _>(reqs, target, None, cfg, 0).unwrap()
}

/// A `fault: None` config must reproduce the pre-injection scheduler
/// byte-for-byte, and a no-op plan (all rates zero) must change nothing
/// observable either: same outputs, same single-attempt accounting.
#[test]
fn disabled_and_noop_injection_reproduce_the_baseline() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 13);
    let target = fixture_target(5);
    let reqs = || fixture_requests(&corpus, 8, 12);

    let baseline = run(reqs(), &target, &ServeCfg::continuous(4));
    assert_serving_contracts(&baseline, 8, 0);
    let noop = run(
        reqs(),
        &target,
        &ServeCfg::continuous(4).with_faults(FaultPlan::default()),
    );
    assert_serving_contracts(&noop, 8, 0);
    assert_outputs_match(&baseline, &noop, "no-op plan vs no injector");
}

/// The injected fault set is keyed per (request, attempt, round), so the
/// terminal outcome, attempt count, and output of every request are
/// identical at 1, 2, and 4 workers — and every request that completes
/// (first try or after retries) decodes bit-identically to sequential.
#[test]
fn chaos_outcomes_are_identical_across_worker_counts() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 17);
    let target = fixture_target(5);
    let n = 9;
    let reqs = || fixture_requests(&corpus, n, 12);
    let sequential = ServingEngine::serve::<Transformer, _>(reqs(), &target, None, 0).unwrap();
    let plan = FaultPlan::default().seeded(23).with_step_errors(0.08).with_nan(0.04);

    let reports: Vec<ServeReport> = [1usize, 2, 4]
        .iter()
        .map(|&w| {
            let cfg = ServeCfg::continuous(4)
                .with_workers(w)
                .with_retries(2)
                .with_backoff(0.25)
                .with_faults(plan.clone());
            let r = run(reqs(), &target, &cfg);
            assert_terminal_outcomes(&r, n, 0);
            r
        })
        .collect();

    // at these rates with 2 retries some request must actually retry,
    // or the test isn't exercising containment at all
    assert!(
        reports[0].retried() > 0,
        "chaos profile injected nothing; raise the rates"
    );

    for (w, r) in [2usize, 4].iter().zip(&reports[1..]) {
        for (a, b) in reports[0].completed.iter().zip(&r.completed) {
            assert_eq!(a.id, b.id, "workers={w}: id sets diverged");
            assert_eq!(a.outcome, b.outcome, "workers={w}: request {} outcome", a.id);
            assert_eq!(a.attempts, b.attempts, "workers={w}: request {} attempts", a.id);
            assert_eq!(a.output, b.output, "workers={w}: request {} output", a.id);
            assert_eq!(a.generated, b.generated, "workers={w}: request {} tokens", a.id);
        }
    }

    // containment/retry never corrupts a completed decode
    for r in &reports {
        for c in r.completed.iter().filter(|c| c.is_completed()) {
            let s = sequential.completed.iter().find(|s| s.id == c.id).unwrap();
            assert_eq!(c.output, s.output, "request {} drifted from sequential", c.id);
        }
    }
}

/// Same plan + same seed → the same report, field for field. Chaos is
/// reproducible, which is what makes failing seeds debuggable. The plan
/// sticks to per-request keyed faults (step errors, poisoned logits) —
/// stall and crash *firing rounds* depend on wall-measured round times,
/// so they are exercised by the dedicated tests above/below instead.
#[test]
fn chaos_runs_are_reproducible() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 29);
    let target = fixture_target(3);
    let reqs = || fixture_requests(&corpus, 8, 10);
    let cfg = ServeCfg::continuous(3)
        .with_workers(2)
        .with_retries(1)
        .with_backoff(0.5)
        .with_faults(
            FaultPlan::default()
                .seeded(41)
                .with_step_errors(0.1)
                .with_nan(0.05),
        );
    let a = run(reqs(), &target, &cfg);
    let b = run(reqs(), &target, &cfg);
    assert_terminal_outcomes(&a, 8, 0);
    assert_eq!(a.completed.len(), b.completed.len());
    for (x, y) in a.completed.iter().zip(&b.completed) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.outcome, y.outcome, "request {}", x.id);
        assert_eq!(x.attempts, y.attempts, "request {}", x.id);
        assert_eq!(x.output, y.output, "request {}", x.id);
    }
    assert_eq!(a.outcome_counts(), b.outcome_counts());
    assert_eq!(a.crashed_workers, b.crashed_workers);
}

/// A worker crash mid-run: its live requests re-enter the shared queue
/// and finish on the survivor (exactly-once, correct outputs), and the
/// crash is logged in the report.
#[test]
fn crashed_worker_requests_complete_on_survivors() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 7);
    let target = fixture_target(5);
    let n = 8;
    let reqs = || fixture_requests(&corpus, n, 12);
    let sequential = ServingEngine::serve::<Transformer, _>(reqs(), &target, None, 0).unwrap();
    let cfg = ServeCfg::continuous(4)
        .with_workers(2)
        .with_retries(3)
        .with_backoff(0.1)
        .with_faults(FaultPlan::default().with_crash(1, 0.0));
    let r = run(reqs(), &target, &cfg);
    assert_terminal_outcomes(&r, n, 0);
    assert_eq!(r.goodput(), n, "survivor absorbs the crashed worker's load");
    assert_eq!(r.crashed_workers.len(), 1);
    assert_eq!(r.crashed_workers[0].0, 1, "worker 1 was the crash target");
    assert_outputs_match(&sequential, &r, "crash+re-admission vs sequential");
}

/// Every worker crashes with work still queued: the pool still returns
/// full accounting — each live request Failed (retries exhausted against
/// dead workers) or the queue Shed — with zero panics.
#[test]
fn total_worker_loss_still_accounts_for_every_request() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 19);
    let target = fixture_target(5);
    let n = 10;
    let cfg = ServeCfg::continuous(2)
        .with_workers(2)
        .with_faults(FaultPlan::default().with_crash(0, 0.0).with_crash(1, 0.0));
    let r = run(fixture_requests(&corpus, n, 12), &target, &cfg);
    assert_terminal_outcomes(&r, n, 0);
    assert_eq!(r.goodput(), 0, "nothing can complete with every worker dead");
    assert_eq!(r.crashed_workers.len(), 2);
    let counts = r.outcome_counts();
    assert_eq!(counts.failed + counts.shed, n);
    assert!(counts.shed > 0, "queued requests shed when the pool dies");
}

/// KV accounting under injection: faulted and cancelled reservations are
/// released, so pool-wide peak live KV stays within the admission budget
/// even while requests fault and retry.
#[test]
fn budget_holds_with_faulted_reservations_released() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 23);
    let target = fixture_target(5);
    let n = 9;
    let reqs = fixture_requests(&corpus, n, 12);
    let worst = reqs.iter().map(|r| projected_greedy(&target, r)).max().unwrap();
    let budget = 2 * (2 * worst + 64); // ~2 concurrent requests per worker
    let cfg = ServeCfg::continuous(8)
        .with_workers(2)
        .with_budget(budget)
        .with_retries(2)
        .with_backoff(0.1)
        .with_faults(FaultPlan::default().seeded(3).with_step_errors(0.15).with_nan(0.05));
    let r = run(reqs, &target, &cfg);
    assert_terminal_outcomes(&r, n, budget);
    assert!(r.peak_kv_bytes > 0, "fixture sessions hold real KV bytes");
}

/// Deadlines on the virtual clock: with every round stalled far past a
/// tight deadline, each request is cancelled — mid-flight with its
/// partial output kept, or before admission ever runs — never completed,
/// never dropped.
#[test]
fn stalls_push_every_request_past_its_deadline() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 31);
    let target = fixture_target(5);
    let n = 6;
    let cfg = ServeCfg::continuous(4)
        .with_workers(2)
        .with_deadline(1.0)
        .with_faults(FaultPlan::default().with_stalls(1.0, 50.0));
    let r = run(fixture_requests(&corpus, n, 12), &target, &cfg);
    assert_terminal_outcomes(&r, n, 0);
    let counts = r.outcome_counts();
    assert_eq!(counts.deadline_exceeded, n, "50ms stalls bust a 1ms deadline");
    assert!(
        r.completed.iter().any(|c| c.generated > 0),
        "mid-flight cancellation keeps partial output"
    );
    assert!(
        r.completed.iter().any(|c| c.attempts == 0),
        "late arrivals are cancelled before admission"
    );
}

/// A per-request deadline overrides the pool default: the request with
/// its own generous deadline survives a pool default that cancels the
/// rest.
#[test]
fn per_request_deadline_overrides_pool_default() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 37);
    let target = fixture_target(5);
    let mut reqs = fixture_requests(&corpus, 4, 8);
    reqs[0].deadline_ms = Some(1e9);
    let cfg = ServeCfg::continuous(4)
        .with_deadline(1.0)
        .with_faults(FaultPlan::default().with_stalls(1.0, 50.0));
    let r = run(reqs, &target, &cfg);
    assert_terminal_outcomes(&r, 4, 0);
    let first = r.completed.iter().find(|c| c.id == 0).unwrap();
    assert_eq!(first.outcome, RequestOutcome::Completed, "own deadline wins");
    for c in r.completed.iter().filter(|c| c.id != 0) {
        assert_eq!(c.outcome, RequestOutcome::DeadlineExceeded, "request {}", c.id);
    }
}

/// Randomized chaos sweep: random traces, budgets, worker counts, and
/// fault profiles — exactly-once terminal outcomes, budget compliance,
/// and completed-output correctness must hold for every seed.
#[test]
fn randomized_chaos_upholds_terminal_contracts() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 4_096, 41);
    let target = fixture_target(7);
    check(6, |rng: &mut Rng| {
        let n = 4 + rng.below(6);
        let mut t = 0.0f64;
        let reqs: Vec<TokenRequest> = (0..n)
            .map(|i| {
                t += rng.f64() * 2.0;
                let start = rng.below(corpus.len() - 12);
                TokenRequest {
                    id: i as u64,
                    prompt: corpus[start..start + 4 + rng.below(8)].to_vec(),
                    max_new_tokens: 1 + rng.below(10),
                    arrival_ms: t,
                    deadline_ms: None,
                    class: Default::default(),
                }
            })
            .collect();
        let sequential =
            ServingEngine::serve::<Transformer, _>(reqs.clone(), &target, None, 0).unwrap();
        let workers = 1 + rng.below(3);
        let worst = reqs.iter().map(|r| projected_greedy(&target, r)).max().unwrap();
        let budget = workers * worst * (1 + rng.below(3));
        let mut plan = FaultPlan::default()
            .seeded(rng.below(1_000_000) as u64)
            .with_step_errors(rng.f64() * 0.2)
            .with_nan(rng.f64() * 0.1)
            .with_stalls(rng.f64() * 0.3, rng.f64() * 2.0);
        if rng.below(2) == 1 && workers > 1 {
            plan = plan.with_crash(rng.below(workers), rng.f64() * 3.0);
        }
        let cfg = ServeCfg::continuous(1 + rng.below(5))
            .with_workers(workers)
            .with_budget(budget)
            .with_retries(rng.below(4))
            .with_backoff(0.1 + rng.f64())
            .with_faults(plan);
        let r = run(reqs, &target, &cfg);
        assert_terminal_outcomes(&r, n, budget);
        for c in r.completed.iter().filter(|c| c.is_completed()) {
            let s = sequential.completed.iter().find(|s| s.id == c.id).unwrap();
            assert_eq!(c.output, s.output, "request {} drifted from sequential", c.id);
        }
    });
}
