//! Quantizer round-trip contracts through the public API: fp8 error
//! bounds, int-affine exactness on representable grids, and bit-exact
//! pack→unpack identity for every storage codec.

use angelslim::quant::packing::{
    pack_2bit, pack_nibbles, pack_sherry, pack_ternary_1_67, unpack_2bit, unpack_nibbles,
    unpack_sherry, unpack_ternary_1_67,
};
use angelslim::quant::{
    fp8_e4m3_qdq, fp8_e5m2_qdq, AffineQuantizer, Fp8Format, Granularity, Sherry,
    TernaryQuantizer, WeightQuantizer,
};
use angelslim::util::testing::check;

// ---------------------------------------------------------------------
// fp8
// ---------------------------------------------------------------------

#[test]
fn fp8_relative_error_bound_across_range() {
    // e4m3 normals: |q - x| / |x| <= 2^-4; e5m2: <= 2^-3
    for (qdq, max, bound) in [
        (fp8_e4m3_qdq as fn(f32) -> f32, 448.0f32, 1.0 / 16.0),
        (fp8_e5m2_qdq, 57344.0, 1.0 / 8.0),
    ] {
        let mut x = 0.02f32;
        while x < max * 0.9 {
            for v in [x, -x] {
                let q = qdq(v);
                let rel = (q - v).abs() / v.abs();
                assert!(rel <= bound + 1e-6, "x={v} q={q} rel={rel}");
            }
            x *= 1.37;
        }
    }
}

#[test]
fn fp8_qdq_is_idempotent() {
    check(16, |rng| {
        for _ in 0..64 {
            let x = (rng.normal()) * 30.0;
            let once = fp8_e4m3_qdq(x);
            assert_eq!(fp8_e4m3_qdq(once), once, "x={x}");
        }
    });
}

#[test]
fn fp8_scaled_slice_preserves_absmax_element() {
    check(8, |rng| {
        let mut xs = rng.normal_vec(64, 0.3);
        xs[17] = 2.5; // known absmax
        let before = xs.clone();
        let scale = angelslim::quant::fp8::qdq_slice_scaled(&mut xs, Fp8Format::E4M3);
        assert!((scale - 2.5 / 448.0).abs() < 1e-9);
        // the absmax element maps exactly onto the top of the fp8 range
        assert!((xs[17] - 2.5).abs() < 1e-6);
        for (a, b) in xs.iter().zip(&before) {
            assert!((a - b).abs() <= b.abs() / 16.0 + 1e-6, "{a} vs {b}");
        }
    });
}

// ---------------------------------------------------------------------
// int affine
// ---------------------------------------------------------------------

#[test]
fn int_affine_exact_roundtrip_on_representable_grid() {
    // weights lying exactly on the code grid (code * 0.125, |code| <= 7,
    // absmax hitting code 7) must round-trip bit-exactly
    check(16, |rng| {
        let (n, k, g) = (4, 64, 32usize);
        let step = 0.125f32; // exactly representable in binary
        let mut w = vec![0.0f32; n * k];
        for row in 0..n {
            for gs in (0..k).step_by(g) {
                w[row * k + gs] = 7.0 * step; // pin the group absmax
                for i in 1..g {
                    let code = rng.below(15) as i32 - 7;
                    w[row * k + gs + i] = code as f32 * step;
                }
            }
        }
        let orig = w.clone();
        AffineQuantizer::new(4, Granularity::Group(g)).qdq(&mut w, n, k);
        assert_eq!(w, orig, "on-grid weights must be fixed points");
    });
}

#[test]
fn int_affine_codes_dequant_matches_qdq() {
    check(8, |rng| {
        let (n, k) = (8, 64);
        let w = rng.normal_vec(n * k, 0.7);
        let q = AffineQuantizer::int4_group32();
        let (codes, scales) = q.quantize_codes(&w, n, k);
        assert!(codes.iter().all(|&c| c <= 15));
        let deq = q.dequantize_codes(&codes, &scales, n, k);
        let mut direct = w.clone();
        q.qdq(&mut direct, n, k);
        angelslim::util::testing::assert_allclose(&deq, &direct, 1e-6, 1e-6);
    });
}

// ---------------------------------------------------------------------
// ternary + packing codecs
// ---------------------------------------------------------------------

#[test]
fn ternary_codes_roundtrip_through_every_codec() {
    check(16, |rng| {
        let codes: Vec<u8> = (0..240).map(|_| rng.below(3) as u8).collect();
        assert_eq!(unpack_2bit(&pack_2bit(&codes)), codes);
        assert_eq!(unpack_ternary_1_67(&pack_ternary_1_67(&codes), 240), codes);
        // 240 ternary digits: 80 base-3 groups * 5 bits = 400 bits = 50 B
        assert_eq!(pack_ternary_1_67(&codes).len(), 50);
        assert_eq!(pack_2bit(&codes).len(), 60);
    });
}

#[test]
fn nibble_and_sherry_codecs_roundtrip() {
    check(16, |rng| {
        let nib: Vec<u8> = (0..128).map(|_| rng.below(16) as u8).collect();
        assert_eq!(unpack_nibbles(&pack_nibbles(&nib)), nib);
        let sherry: Vec<u8> = (0..56).map(|_| rng.below(32) as u8).collect();
        assert_eq!(unpack_sherry(&pack_sherry(&sherry), 56), sherry);
        assert_eq!(pack_sherry(&sherry).len(), 35); // 56 * 5 bits = 280 bits
    });
}

#[test]
fn ternary_quantize_dequantize_identity_on_codes() {
    check(8, |rng| {
        let (n, k) = (8, 48);
        let w = rng.normal_vec(n * k, 1.0);
        let q = TernaryQuantizer::default();
        let (codes, alphas) = q.quantize_codes(&w, n, k);
        assert!(codes.iter().all(|&c| c <= 2));
        assert_eq!(alphas.len(), n);
        let deq = TernaryQuantizer::dequantize_codes(&codes, &alphas, n, k);
        // re-encoding the dequantized tensor reproduces the same codes
        let (codes2, _) = q.quantize_codes(&deq, n, k);
        assert_eq!(codes2, codes, "ternary code image must be stable");
    });
}

#[test]
fn sherry_codes_roundtrip_and_hold_3_4_sparsity() {
    check(8, |rng| {
        let (n, k) = (6, 64);
        let w = rng.normal_vec(n * k, 1.0);
        let (codes, alphas) = Sherry::quantize_codes(&w, n, k);
        assert_eq!(codes.len(), n * k / 4);
        assert!(codes.iter().all(|&c| c < 32));
        let deq = Sherry::dequantize_codes(&codes, &alphas, n, k);
        let nz = deq.iter().filter(|&&v| v != 0.0).count();
        assert_eq!(nz, n * k * 3 / 4);
        // pack → unpack → dequantize agrees with the direct dequant
        let unpacked = unpack_sherry(&pack_sherry(&codes), codes.len());
        assert_eq!(unpacked, codes);
    });
}
