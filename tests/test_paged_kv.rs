//! Property tests for the paged KV cache and the paged serving path:
//! pool invariants must hold under randomized session op sequences
//! (append / truncate / clear / prefix attach+seal, with exhaustion and
//! prefix-cache reclaim in play), and paged serving — greedy and
//! speculative, at 1/2/4 workers, fault-free and under seeded chaos —
//! must decode bit-identically to the contiguous executors while
//! materializing shared prompt prefixes once per worker instead of once
//! per request.

use std::sync::{Arc, Mutex};

use angelslim::data::TokenRequest;
use angelslim::models::{BlockPool, PagedKvCache, Transformer};
use angelslim::server::{FaultPlan, ServeCfg, ServeReport, ServingEngine};
use angelslim::util::fixtures::{fixture_draft, fixture_target};
use angelslim::util::testing::{assert_outputs_match, assert_terminal_outcomes, check};
use angelslim::util::Rng;

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

/// Shared-prefix trace: every request carries the same `prompt_len`-token
/// prompt (a planted-rule walk, so greedy decoding is meaningful). All
/// requests arrive together so concurrency is pinned by `max_in_flight`,
/// not by how fast the fixture model happens to decode a round — the
/// residency assertions below need the prompts live at the same time.
fn shared_prefix_reqs(n: usize, prompt_len: usize, max_new: usize) -> Vec<TokenRequest> {
    let prompt: Vec<u8> = (0..prompt_len).map(|i| ((i * 5) % 32) as u8).collect();
    (0..n)
        .map(|i| TokenRequest {
            id: i as u64,
            prompt: prompt.clone(),
            max_new_tokens: max_new,
            arrival_ms: 0.0,
            deadline_ms: None,
            class: Default::default(),
        })
        .collect()
}

/// Mixed trace with distinct prompts and heterogeneous lengths.
fn mixed_reqs(n: usize, max_new: usize) -> Vec<TokenRequest> {
    (0..n)
        .map(|i| TokenRequest {
            id: i as u64,
            prompt: (0..6 + i % 3).map(|j| ((i * 7 + j * 3) % 32) as u8).collect(),
            max_new_tokens: if i % 2 == 0 { max_new } else { max_new / 3 + 1 },
            arrival_ms: i as f64 * 0.5,
            deadline_ms: None,
            class: Default::default(),
        })
        .collect()
}

// ─────────────────────────────────────────────────────────────────────
// Pool-level properties
// ─────────────────────────────────────────────────────────────────────

/// Randomized op sequences over several sessions on one bounded pool:
/// after every operation the pool's refcount / free-list / prefix-cache
/// partition must stay consistent, failed appends must be atomic (the
/// mirror sequence and cache length never diverge), and dropping every
/// session must return every page to free or the prefix cache.
#[test]
fn pool_invariants_hold_under_random_op_sequences() {
    check(24, |rng: &mut Rng| {
        let bt = 4usize;
        let pool = Arc::new(Mutex::new(BlockPool::new_bounded(
            2,
            8,
            bt,
            12 * 2 * 2 * bt * 8 * 4, // 12 pages
        )));
        let mut caches: Vec<PagedKvCache> =
            (0..3).map(|_| PagedKvCache::new(Arc::clone(&pool))).collect();
        let mut mirrors: Vec<Vec<u8>> = vec![Vec::new(); caches.len()];

        for _ in 0..80 {
            let ci = rng.below(caches.len());
            match rng.below(5) {
                // append 1..=6 tokens (prefill or decode-sized)
                0 | 1 => {
                    let k = 1 + rng.below(6);
                    let tokens: Vec<u8> = (0..k).map(|_| rng.below(32) as u8).collect();
                    match caches[ci].prepare_append(k) {
                        Ok(()) => {
                            caches[ci].advance(k);
                            mirrors[ci].extend_from_slice(&tokens);
                        }
                        Err(e) => {
                            // atomic failure: nothing grew
                            assert!(e.needed_blocks > 0);
                            assert_eq!(caches[ci].len(), mirrors[ci].len());
                        }
                    }
                }
                // truncate to a random prefix (whole pages released)
                2 => {
                    let keep = rng.below(mirrors[ci].len() + 1);
                    caches[ci].truncate(keep);
                    mirrors[ci].truncate(keep);
                }
                // seal the full pages so other sessions can attach them
                3 => {
                    let seq = mirrors[ci].clone();
                    caches[ci].seal_prefix(&seq);
                }
                // restart the session from a donor's sealed prefix
                _ => {
                    caches[ci].clear();
                    mirrors[ci].clear();
                    let donor = mirrors[(ci + 1) % mirrors.len()].clone();
                    if !donor.is_empty() {
                        let matched = caches[ci].attach_prefix(&donor);
                        assert!(matched % bt == 0, "attach matches whole pages only");
                        assert!(matched <= donor.len());
                        match caches[ci].prepare_append(donor.len()) {
                            Ok(()) => {
                                caches[ci].advance(donor.len());
                                mirrors[ci] = donor;
                            }
                            Err(_) => {
                                caches[ci].clear();
                            }
                        }
                    }
                }
            }
            assert_eq!(caches[ci].len(), mirrors[ci].len(), "cache/mirror drifted");
            pool.lock().unwrap().check_invariants();
        }

        drop(caches);
        let p = pool.lock().unwrap();
        p.check_invariants();
        assert_eq!(
            p.in_use_blocks(),
            0,
            "dropped sessions must release every page (cached prefixes excluded)"
        );
        assert!(p.total_blocks() <= 12 || p.max_blocks() == 0, "cap respected");
    });
}

/// Two sessions over the same sealed prompt share pages; diverging past
/// the prefix forks copy-on-write and never rewrites the shared rows.
#[test]
fn attach_then_diverge_forks_instead_of_corrupting_the_shared_page() {
    let bt = 4usize;
    let pool = Arc::new(Mutex::new(BlockPool::new(2, 8, bt)));
    let prompt: Vec<u8> = (0..6).map(|i| i as u8).collect(); // 1 full + 1 partial page

    let mut a = PagedKvCache::new(Arc::clone(&pool));
    assert_eq!(a.attach_prefix(&prompt), 0, "nothing sealed yet");
    a.prepare_append(prompt.len()).unwrap();
    a.advance(prompt.len());
    a.seal_prefix(&prompt);

    let mut b = PagedKvCache::new(Arc::clone(&pool));
    assert_eq!(b.attach_prefix(&prompt), bt, "full page attaches, partial does not");
    b.prepare_append(prompt.len()).unwrap();
    b.advance(prompt.len());
    assert_eq!(b.table()[0], a.table()[0], "first page shared");
    assert_ne!(b.table()[1], a.table()[1], "partial page is private");
    assert_eq!(pool.lock().unwrap().refcount(a.table()[0]), 2);

    // rolling back *into* the shared page and diverging must fork it
    // copy-on-write: b gets a private copy of the first two rows while
    // a's view and the sealed index entry stay untouched
    b.truncate(2);
    assert_eq!(pool.lock().unwrap().refcount(a.table()[0]), 2, "rollback into a page keeps the ref");
    b.prepare_append(1).unwrap();
    b.advance(1);
    assert_ne!(b.table()[0], a.table()[0], "mid-page divergence forked the shared page");
    assert_eq!(pool.lock().unwrap().refcount(a.table()[0]), 1, "b dropped its shared ref");
    assert!(pool.lock().unwrap().is_sealed(a.table()[0]), "shared page stays sealed for reuse");
    assert_eq!(a.len(), 6);
    assert_eq!(b.len(), 3);
    pool.lock().unwrap().check_invariants();
}

// ─────────────────────────────────────────────────────────────────────
// Serving equivalence: paged vs contiguous
// ─────────────────────────────────────────────────────────────────────

fn flat_greedy(reqs: Vec<TokenRequest>, model: &Transformer, cfg: &ServeCfg) -> ServeReport {
    ServingEngine::serve_scheduled::<Transformer, _>(reqs, model, None, cfg, 0).unwrap()
}

/// Greedy paged serving is bit-identical to contiguous serving at every
/// worker count, on a mixed trace and on a fully-shared-prefix trace.
#[test]
fn paged_greedy_matches_contiguous_at_every_worker_count() {
    let model = fixture_target(3);
    for &w in &WORKER_COUNTS {
        let cfg = ServeCfg::continuous(4).with_workers(w);
        let paged_cfg = cfg.clone().with_block_tokens(4);
        for (name, reqs, n) in [
            ("mixed", mixed_reqs(9, 10), 9),
            ("shared-prefix", shared_prefix_reqs(6, 8, 6), 6),
        ] {
            let flat = flat_greedy(reqs.clone(), &model, &cfg);
            let paged =
                ServingEngine::serve_paged(reqs, &model, None, &paged_cfg, 0).unwrap();
            assert_terminal_outcomes(&paged, n, 0);
            assert_outputs_match(
                &flat,
                &paged,
                &format!("paged greedy vs contiguous ({name}, workers={w})"),
            );
        }
    }
}

/// Speculative paged serving (draft + target, separate pools) matches the
/// contiguous speculative executor at every worker count.
#[test]
fn paged_spec_matches_contiguous_at_every_worker_count() {
    let draft = fixture_draft(3);
    let target = fixture_target(3);
    for &w in &WORKER_COUNTS {
        let cfg = ServeCfg::continuous(3).with_workers(w);
        let reqs = || shared_prefix_reqs(6, 8, 10);
        let flat = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg,
            0,
        )
        .unwrap();
        let paged = ServingEngine::serve_paged(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg.clone().with_block_tokens(4),
            0,
        )
        .unwrap();
        assert_outputs_match(&flat, &paged, &format!("paged spec vs contiguous, workers={w}"));
        assert!(paged.mean_al > 1.0, "speculation still accepts proposals");
    }
}

/// A shared-prefix trace materializes the prompt's pages once per worker:
/// paged peak resident KV stays strictly below N x the prompt's KV bytes,
/// while the contiguous path pays the full per-request copy.
#[test]
fn shared_prefix_trace_is_resident_once_not_once_per_request() {
    let model = fixture_target(3);
    let n = 6;
    let prompt_len = 16; // two full 8-token pages, shared across all N
    let reqs = || shared_prefix_reqs(n, prompt_len, 2);
    let cfg = ServeCfg::continuous(4);
    let flat = flat_greedy(reqs(), &model, &cfg);
    let paged = ServingEngine::serve_paged(
        reqs(),
        &model,
        None,
        &cfg.clone().with_block_tokens(8),
        0,
    )
    .unwrap();
    assert_outputs_match(&flat, &paged, "shared-prefix paged vs contiguous");

    let n_prompt_bytes = n * prompt_len * model.cfg.kv_bytes_per_token();
    assert!(
        paged.peak_kv_bytes < n_prompt_bytes,
        "shared prompts must be resident once: paged peak {} >= {} (= {n} x prompt)",
        paged.peak_kv_bytes,
        n_prompt_bytes
    );
    assert!(
        paged.peak_kv_bytes < flat.peak_kv_bytes,
        "paged peak {} must undercut contiguous peak {}",
        paged.peak_kv_bytes,
        flat.peak_kv_bytes
    );
}

/// Same seed, same trace → field-identical paged reports (preemption and
/// prefix sharing are deterministic).
#[test]
fn paged_serving_is_reproducible() {
    let model = fixture_target(5);
    let block_bytes = 4 * model.cfg.kv_bytes_per_token();
    let cfg = ServeCfg::continuous(4)
        .with_budget(5 * block_bytes)
        .with_block_tokens(4);
    let run = || {
        ServingEngine::serve_paged(mixed_reqs(6, 10), &model, None, &cfg, 0).unwrap()
    };
    let (a, b) = (run(), run());
    assert_outputs_match(&a, &b, "paged determinism");
    for (x, y) in a.completed.iter().zip(&b.completed) {
        assert_eq!(x.outcome, y.outcome, "request {} outcome drifted", x.id);
        assert_eq!(x.attempts, y.attempts, "request {} attempts drifted", x.id);
    }
    assert_eq!(a.peak_kv_bytes, b.peak_kv_bytes);
}

/// Preemption under a page-starved pool re-queues work instead of
/// failing it, and every completed output still matches the contiguous
/// run — restart-from-scratch recomputes the identical greedy decode.
#[test]
fn preemption_under_page_pressure_keeps_outputs_bit_identical() {
    let model = fixture_target(3);
    let block_bytes = 4 * model.cfg.kv_bytes_per_token();
    // the longest request peaks at 5 pages (fits alone, so the overcommit
    // valve never fires), but two concurrent longs need 10 — preemption
    // territory
    let budget = 6 * block_bytes;
    let reqs = || mixed_reqs(5, 12);
    let flat = flat_greedy(reqs(), &model, &ServeCfg::continuous(4));
    let paged = ServingEngine::serve_paged(
        reqs(),
        &model,
        None,
        &ServeCfg::continuous(4).with_budget(budget).with_block_tokens(4),
        0,
    )
    .unwrap();
    assert_terminal_outcomes(&paged, 5, budget);
    assert_eq!(paged.goodput(), 5, "preemption must never strand a request");
    assert_outputs_match(&flat, &paged, "preempted paged vs unbudgeted contiguous");
}

/// Seeded chaos (step errors + poisoned logits) on the paged path: every
/// request still reaches exactly one terminal outcome, and every request
/// that completes decodes bit-identically to fault-free sequential —
/// containment plus paged restart never corrupt a decode.
#[test]
fn chaos_on_the_paged_path_contains_faults_without_corrupting_outputs() {
    let model = fixture_target(5);
    let n = 8;
    let reqs = || mixed_reqs(n, 10);
    let sequential =
        ServingEngine::serve::<Transformer, _>(reqs(), &model, None, 0).unwrap();

    let block_bytes = 4 * model.cfg.kv_bytes_per_token();
    let plan = FaultPlan::default().seeded(31).with_step_errors(0.05).with_nan(0.03);
    for &w in &[1usize, 2] {
        let cfg = ServeCfg::continuous(4)
            .with_workers(w)
            .with_budget(w * 6 * block_bytes)
            .with_block_tokens(4)
            .with_retries(8)
            .with_backoff(0.25)
            .with_faults(plan.clone());
        let r = ServingEngine::serve_paged(reqs(), &model, None, &cfg, 0).unwrap();
        assert_terminal_outcomes(&r, n, 0);
        for c in r.completed.iter().filter(|c| c.is_completed()) {
            let s = sequential.completed.iter().find(|s| s.id == c.id).unwrap();
            assert_eq!(
                c.output, s.output,
                "workers={w}: request {} drifted from sequential under chaos",
                c.id
            );
        }
    }
}
