//! Every shipped config in configs/ must parse into a valid `SlimConfig`
//! and name a registered method/algorithm — the same validation
//! `angelslim list` performs.

use angelslim::config::SlimConfig;
use angelslim::coordinator::SlimFactory;

#[test]
fn all_shipped_configs_parse_and_validate() {
    let mut seen = 0usize;
    for entry in std::fs::read_dir("configs").expect("configs/ directory missing") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "yaml").unwrap_or(false) {
            let p = path.to_str().unwrap();
            let cfg = SlimConfig::from_file(p)
                .unwrap_or_else(|e| panic!("config {p} failed to parse: {e:#}"));
            SlimFactory::validate(&cfg)
                .unwrap_or_else(|e| panic!("config {p} failed validation: {e:#}"));
            seen += 1;
        }
    }
    // guard against the directory silently emptying out
    assert!(seen >= 4, "expected at least 4 shipped configs, found {seen}");
}

#[test]
fn fixture_configs_target_registered_fixture_model() {
    let cfg = SlimConfig::from_file("configs/quant_int4_fixture.yaml").unwrap();
    assert_eq!(cfg.model.name, "tiny-fixture");
    assert_eq!(cfg.dataset.kind, "fixture");
    assert_eq!(cfg.compression.method, "quantization");
    assert_eq!(cfg.compression.algo, "int4");
}
