//! Every shipped config in configs/ must parse into a valid `SlimConfig`
//! whose pipeline stages (explicit `pipeline:` or desugared legacy
//! `compression.method` form) name registered passes — the same
//! registry-driven validation `angelslim list` performs — and both
//! compression-pipeline and serving misconfigurations must fail loudly at
//! parse/startup instead of silently falling back.

use angelslim::config::SlimConfig;
use angelslim::coordinator::{PassRegistry, SlimFactory};
use angelslim::data::{markov_corpus, RequestGen, TokenRequest};
use angelslim::models::Transformer;
use angelslim::server::{
    GreedyExecutor, PagedGreedyExecutor, ServeCfg, ServingEngine, StepExecutor,
};
use angelslim::util::fixtures::fixture_target;

/// Minimal valid config with an arbitrary `serve:` section appended.
fn with_serve(serve_yaml: &str) -> Result<SlimConfig, anyhow::Error> {
    SlimConfig::from_str(&format!(
        "model:\n  name: tiny-fixture\ncompression:\n  method: quantization\nserve:\n{serve_yaml}"
    ))
}

#[test]
fn all_shipped_configs_parse_and_validate() {
    let mut seen = 0usize;
    let mut multi_stage = 0usize;
    for entry in std::fs::read_dir("configs").expect("configs/ directory missing") {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e == "yaml").unwrap_or(false) {
            let p = path.to_str().unwrap();
            let cfg = SlimConfig::from_file(p)
                .unwrap_or_else(|e| panic!("config {p} failed to parse: {e:#}"));
            SlimFactory::validate(&cfg)
                .unwrap_or_else(|e| panic!("config {p} failed validation: {e:#}"));
            // pipeline invariants every config upholds (legacy forms
            // desugar to exactly one stage; every stage is registered)
            assert!(!cfg.pipeline.is_empty(), "{p}: empty pipeline");
            for stage in &cfg.pipeline {
                assert!(
                    PassRegistry::find(&stage.pass).is_some(),
                    "{p}: stage `{}` not in the PassRegistry",
                    stage.pass
                );
            }
            if cfg.pipeline.len() > 1 {
                multi_stage += 1;
            }
            seen += 1;
        }
    }
    // guard against the directory silently emptying out
    assert!(seen >= 4, "expected at least 4 shipped configs, found {seen}");
    assert!(
        multi_stage >= 2,
        "expected the two shipped multi-stage pipeline fixtures, found {multi_stage}"
    );
}

#[test]
fn legacy_single_method_form_desugars_to_one_stage() {
    let cfg = SlimConfig::from_str(
        "model:\n  name: tiny-fixture\ncompression:\n  method: quantization\n  \
         quantization:\n    algo: gptq\n",
    )
    .unwrap();
    assert_eq!(cfg.pipeline.len(), 1);
    assert_eq!(cfg.pipeline[0].pass, "gptq");
    assert_eq!(cfg.pipeline[0].params, cfg.compression);
}

#[test]
fn pipeline_rejects_unknown_pass_names() {
    let err = SlimConfig::from_str(
        "model:\n  name: tiny-fixture\npipeline:\n  - pass: wizardry\n",
    )
    .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("wizardry") && msg.contains("registered"), "{msg}");
    // same loud failure through the legacy spelling
    assert!(SlimConfig::from_str(
        "model:\n  name: tiny-fixture\ncompression:\n  method: quantization\n  \
         quantization:\n    algo: wizardry\n",
    )
    .is_err());
}

#[test]
fn pipeline_rejects_empty_and_malformed_sections() {
    for bad in [
        "pipeline: []\n",
        "pipeline:\n",
        "pipeline: gptq\n",
        "pipeline:\n  - 17\n",
    ] {
        assert!(
            SlimConfig::from_str(&format!("model:\n  name: tiny-fixture\n{bad}")).is_err(),
            "must reject: {bad:?}"
        );
    }
}

#[test]
fn pipeline_rejects_invalid_per_stage_overrides() {
    for bad in [
        "  - pass: int4\n    bits: 99\n",
        "  - pass: int4\n    bits: -4\n",
        "  - pass: idpruner\n    ratio: 0.0\n",
        "  - pass: stem\n    ratio: 1.5\n",
        "  - pass: smooth\n    smooth_alpha: 2.0\n",
        "  - pass: gptq\n    low_memory_budget_layers: -1\n",
        "  - pass: gptq\n    group_size: -32\n",
    ] {
        assert!(
            SlimConfig::from_str(&format!("model:\n  name: tiny-fixture\npipeline:\n{bad}"))
                .is_err(),
            "stage override must fail loudly: {bad:?}"
        );
    }
}

#[test]
fn fixture_configs_target_registered_fixture_model() {
    let cfg = SlimConfig::from_file("configs/quant_int4_fixture.yaml").unwrap();
    assert_eq!(cfg.model.name, "tiny-fixture");
    assert_eq!(cfg.dataset.kind, "fixture");
    assert_eq!(cfg.compression.method, "quantization");
    assert_eq!(cfg.compression.algo, "int4");
}

#[test]
fn sharded_config_parses_with_worker_count() {
    let cfg = SlimConfig::from_file("configs/serve_sharded_fixture.yaml").unwrap();
    assert_eq!(cfg.serve.workers, 4);
    assert_eq!(cfg.serve.max_in_flight, 4);
    // the split leaves every worker a real share
    assert!(cfg.serve.per_worker_budgets().iter().all(|&b| b > 0));
}

#[test]
fn serve_rejects_zero_or_negative_workers() {
    assert!(
        with_serve("  workers: 0\n").is_err(),
        "workers: 0 must be a loud error, not a silent single worker"
    );
    assert!(
        with_serve("  workers: -2\n").is_err(),
        "negative workers must not wrap to a huge pool"
    );
    assert_eq!(with_serve("  workers: 3\n").unwrap().serve.workers, 3);
}

#[test]
fn serve_rejects_unknown_policy_strings() {
    assert!(
        with_serve("  policy: psychic\n").is_err(),
        "unknown policy must not fall back to a default"
    );
    assert!(with_serve("  policy: continuous\n").is_ok());
}

#[test]
fn fault_fixture_parses_into_a_full_chaos_profile() {
    let cfg = SlimConfig::from_file("configs/serve_faults_fixture.yaml").unwrap();
    assert_eq!(cfg.serve.workers, 2);
    assert_eq!(cfg.serve.deadline_ms, Some(50_000.0));
    assert_eq!(cfg.serve.max_retries, 3);
    assert!((cfg.serve.retry_backoff_ms - 0.5).abs() < 1e-12);
    let plan = cfg.serve.fault.as_ref().expect("fixture ships a fault block");
    assert_eq!(plan.seed, 7);
    assert!(plan.step_error_rate > 0.0 && plan.nan_rate > 0.0);
    assert_eq!(plan.crashes.len(), 1);
    assert_eq!(plan.crashes[0].worker, 1);
    assert!(!plan.is_noop());
}

#[test]
fn serve_rejects_misconfigured_fault_tolerance() {
    // a zero/negative deadline would cancel every request at admission
    assert!(with_serve("  deadline_ms: 0\n").is_err(), "deadline_ms: 0 must be loud");
    assert!(with_serve("  deadline_ms: -10\n").is_err(), "negative deadline must be loud");
    // negative backoff would schedule retries into the past
    assert!(
        with_serve("  fault:\n    seed: 1\n  retry_backoff_ms: -1\n").is_err(),
        "negative retry_backoff_ms must be loud"
    );
    // retry knobs without a fault block are dead config
    assert!(
        with_serve("  max_retries: 2\n").is_err(),
        "max_retries without a fault block must be rejected"
    );
    // an unknown fault kind must not be silently ignored chaos
    assert!(
        with_serve("  fault:\n    cosmic_rays: 0.5\n").is_err(),
        "unknown fault knob must be rejected"
    );
    // rates are probabilities; crashes need both halves of the pair
    assert!(with_serve("  fault:\n    step_error_rate: 2.0\n").is_err());
    assert!(with_serve("  fault:\n    crash_worker: 0\n").is_err());
    // and the valid spelling of all of the above parses
    assert!(with_serve(
        "  workers: 2\n  deadline_ms: 100\n  max_retries: 1\n\
         \x20 fault:\n    seed: 3\n    step_error_rate: 0.1\n\
         \x20   crash_worker: 1\n    crash_at_ms: 5\n"
    )
    .is_ok());
}

#[test]
fn paged_fixture_parses_and_selects_the_paged_path() {
    let cfg = SlimConfig::from_file("configs/serve_paged_fixture.yaml").unwrap();
    assert_eq!(cfg.serve.kv_block_tokens, Some(8));
    assert_eq!(cfg.serve.workers, 2);
    assert!(cfg.serve.per_worker_budgets().iter().all(|&b| b > 0));
    // contiguous fixtures keep the key absent (contiguous path)
    let sharded = SlimConfig::from_file("configs/serve_sharded_fixture.yaml").unwrap();
    assert_eq!(sharded.serve.kv_block_tokens, None);
}

#[test]
fn slo_fixture_parses_and_serves_a_mixed_class_trace() {
    let cfg = SlimConfig::from_file("configs/serve_slo_fixture.yaml").unwrap();
    let policy = cfg.serve.classes.clone().expect("fixture ships a classes block");
    assert!((policy.aging_ms - 250.0).abs() < 1e-12);
    assert_eq!(policy.sparse_block, 8);
    assert!((policy.multimodal_retain - 0.5).abs() < 1e-12);
    assert_eq!(policy.interactive.priority, 3);
    assert_eq!(policy.batch.priority, 0);
    assert_eq!(policy.batch.deadline_ms, Some(120_000.0));
    policy.validate().unwrap();

    // end to end on the hermetic fixture: the classes block routes
    // long-context prefills through the sparse path and prunes
    // multimodal prompts before KV admission
    let target = fixture_target(5);
    let mut gen = RequestGen::new(markov_corpus(8192, 3), 13);
    gen.prompt_len = 6;
    gen.max_new_tokens = 8;
    let requests = gen.take_mixed_classes(2, 5, 10.0, 24, 8, 4);
    let report = ServingEngine::serve_scheduled::<Transformer, _>(
        requests, &target, None, &cfg.serve, 13,
    )
    .unwrap();
    assert_eq!(report.completed.len(), 10);
    assert!(report.sparse_prefills > 0, "LongContext must route sparse");
    assert!(report.pruned_prompt_tokens > 0, "Multimodal must be pruned");
    let rows = report.class_breakdown(&policy);
    assert_eq!(rows.len(), 4);
    assert_eq!(rows.iter().map(|r| r.total()).sum::<usize>(), 10);
}

#[test]
fn serve_rejects_invalid_kv_block_tokens() {
    assert!(
        with_serve("  kv_block_tokens: 0\n").is_err(),
        "kv_block_tokens: 0 must be a loud error, not a zero-sized page"
    );
    assert!(
        with_serve("  kv_block_tokens: -8\n").is_err(),
        "negative kv_block_tokens must not wrap to usize"
    );
    assert!(
        with_serve("  kv_block_tokens: many\n").is_err(),
        "non-numeric kv_block_tokens must be rejected"
    );
    assert_eq!(
        with_serve("  kv_block_tokens: 16\n").unwrap().serve.kv_block_tokens,
        Some(16)
    );
}

#[test]
fn paged_admission_needs_only_prompt_pages() {
    // the startup guard prices paged admission at the prompt's pages,
    // not the projected peak — a budget too small for the contiguous
    // path can still be valid for the paged one
    let target = fixture_target(5);
    let flat = GreedyExecutor::new(&target);
    let paged = PagedGreedyExecutor::new(&target, 4, 0);
    let requests = vec![TokenRequest {
        id: 0,
        prompt: vec![1, 2, 3, 4],
        max_new_tokens: 16,
        arrival_ms: 0.0,
        deadline_ms: None,
        class: Default::default(),
    }];
    let peak_need = flat.projected_bytes(&requests[0]);
    let prompt_need = paged.admission_bytes(&requests[0]);
    assert!(
        prompt_need < peak_need,
        "prompt pages ({prompt_need}) must undercut projected peak ({peak_need})"
    );
    let cfg = ServeCfg::continuous(4).with_budget(prompt_need);
    assert!(
        cfg.ensure_requests_fit(&flat, &requests).is_err(),
        "too small for projected-peak admission"
    );
    assert!(
        cfg.ensure_requests_fit(&paged, &requests).is_ok(),
        "but enough for free-block admission"
    );
}

#[test]
fn serve_rejects_budget_below_the_smallest_request() {
    // config-level: a total budget that splits to zero per worker
    assert!(
        with_serve("  workers: 8\n  kv_budget_bytes: 3\n").is_err(),
        "budget below the worker count leaves workers effectively unlimited"
    );

    // startup-level: a per-worker share smaller than the smallest
    // request's projected peak KV would silently push *every* request
    // through the oversized-request safety valve — `ensure_requests_fit`
    // (the `angelslim serve --config` guard) must flag it instead
    let target = fixture_target(5);
    let exec = GreedyExecutor::new(&target);
    let requests = vec![TokenRequest {
        id: 0,
        prompt: vec![1, 2, 3, 4],
        max_new_tokens: 8,
        arrival_ms: 0.0,
        deadline_ms: None,
        class: Default::default(),
    }];
    let need = exec.projected_bytes(&requests[0]);
    assert!(need > 0, "fixture requests project real KV bytes");

    let bad = ServeCfg::continuous(4).with_workers(2).with_budget(2 * (need - 1));
    assert!(
        bad.ensure_requests_fit(&exec, &requests).is_err(),
        "budget below the smallest request must error loudly"
    );
    let ok = ServeCfg::continuous(4).with_workers(2).with_budget(2 * need);
    assert!(ok.ensure_requests_fit(&exec, &requests).is_ok());
    // unlimited budget never errors
    assert!(ServeCfg::continuous(4)
        .ensure_requests_fit(&exec, &requests)
        .is_ok());
}
