//! Integration: PJRT artifacts vs the pure-Rust transformer — the two
//! execution paths must agree on the numbers, proving the AOT bridge
//! (jax -> HLO text -> xla crate) carries the trained weights faithfully.
//!
//! Every test here needs BOTH the `pjrt` cargo feature (vendored `xla`
//! crate) and `artifacts/` from `make artifacts`, so they are `#[ignore]`d
//! — a clean `cargo test` reports them as ignored instead of silently
//! passing, and `cargo test -- --ignored` fails loudly when the
//! prerequisites are absent. The hermetic pipeline equivalents (fixture
//! model, no artifacts) live in tests/test_pipeline_hermetic.rs.

use angelslim::models::{AttnOverride, Transformer, WeightStore};
use angelslim::runtime::ArtifactRegistry;
use angelslim::spec_decode::{LogitsModel, SpecDecoder, VanillaDecoder};
use angelslim::util::{testing::assert_allclose, Rng};

const IGNORE_REASON_HELP: &str =
    "artifacts missing — run `make artifacts` and build with `--features pjrt` \
     before `cargo test -- --ignored`";

fn require_artifacts() {
    assert!(
        std::path::Path::new("artifacts/weights.bin").exists()
            && std::path::Path::new("artifacts/model_target_fp32_b1.hlo.txt").exists(),
        "{IGNORE_REASON_HELP}"
    );
}

#[test]
#[ignore = "needs `--features pjrt` + artifacts/ from `make artifacts`"]
fn pjrt_matches_pure_rust_forward() {
    require_artifacts();
    let mut reg = ArtifactRegistry::open("artifacts").unwrap();
    let exe = reg.model("model_target_fp32_b1").unwrap();
    let ws = WeightStore::load("artifacts").unwrap();
    let rust_model = Transformer::from_store(&ws, "target").unwrap();

    let tokens: Vec<u8> = b"Angel quant sparse".to_vec();
    // NOTE: the PJRT artifact runs at fixed T=64 with zero-padding; under
    // causal attention the first `len` positions are unaffected by padding.
    let pjrt = exe.run_padded(&tokens).unwrap();
    let rust = rust_model.forward(&tokens, &AttnOverride::None);
    for (p, row) in pjrt.iter().enumerate() {
        assert_allclose(row, rust.row(p), 2e-3, 2e-3);
    }
}

#[test]
#[ignore = "needs `--features pjrt` + artifacts/ from `make artifacts`"]
fn quantized_artifacts_degrade_in_order() {
    require_artifacts();
    let mut reg = ArtifactRegistry::open("artifacts").unwrap();
    let eval = std::fs::read("artifacts/eval_corpus.bin").unwrap();
    let seq = &eval[..48];

    let nll = |name: &str, reg: &mut ArtifactRegistry| -> f64 {
        let exe = reg.model(name).unwrap();
        let rows = exe.run_padded(seq).unwrap();
        let mut total = 0.0f64;
        for p in 0..seq.len() - 1 {
            let lp = angelslim::tensor::ops::log_softmax(&rows[p]);
            total -= lp[seq[p + 1] as usize] as f64;
        }
        total / (seq.len() - 1) as f64
    };

    let fp32 = nll("model_target_fp32_b1", &mut reg);
    let fp8 = nll("model_target_fp8_b1", &mut reg);
    let int4 = nll("model_target_int4_b1", &mut reg);
    let seq2_ptq = nll("model_target_seq2_b1", &mut reg);
    let seq2_qat = nll("model_target_seq2qat_b1", &mut reg);

    // paper shape: fp8 ~ fp32 < int4 << seq2-PTQ; QAT recovers most of it
    assert!(fp8 < fp32 + 0.1, "fp8 {fp8} vs fp32 {fp32}");
    assert!(int4 < seq2_ptq, "int4 {int4} vs seq2 PTQ {seq2_ptq}");
    assert!(
        seq2_qat < seq2_ptq - 0.2,
        "QAT {seq2_qat} must recover vs PTQ {seq2_ptq}"
    );
    // QAT lands near fp32 (the extra fine-tune steps can even edge past it
    // on this tiny model — the paper's "-3.97% vs FP16" shape)
    assert!(seq2_qat < fp32 + 0.3, "fp32 {fp32} vs seq2_qat {seq2_qat}");
}

#[test]
#[ignore = "needs `--features pjrt` + artifacts/ from `make artifacts`"]
fn spec_decode_on_pjrt_models_is_output_identical_and_accepts() {
    require_artifacts();
    let mut reg = ArtifactRegistry::open("artifacts").unwrap();
    let target = reg.model("model_target_fp32_b1").unwrap();
    let draft = reg.model("model_draft_fp32_b1").unwrap();
    let mut rng = Rng::new(0);

    let eval = std::fs::read("artifacts/eval_corpus.bin").unwrap();
    let prompt = &eval[100..116];

    let (vseq, _) = VanillaDecoder::new(&target)
        .generate(prompt, 32, &mut rng)
        .unwrap();
    let (sseq, stats) = SpecDecoder::new(&draft, &target, 3)
        .generate(prompt, 32, &mut rng)
        .unwrap();
    assert_eq!(vseq, sseq, "speculative decoding changed the output");
    assert!(
        stats.al() > 1.2,
        "distilled draft should be accepted sometimes, AL {}",
        stats.al()
    );
    assert!(stats.acceptance_rate() > 0.2, "{}", stats.acceptance_rate());
}

#[test]
#[ignore = "needs `--features pjrt` + artifacts/ from `make artifacts`"]
fn draft_artifact_agrees_with_rust_draft() {
    require_artifacts();
    let mut reg = ArtifactRegistry::open("artifacts").unwrap();
    let exe = reg.model("model_draft_fp32_b1").unwrap();
    let ws = WeightStore::load("artifacts").unwrap();
    let rust_model = Transformer::from_store(&ws, "draft").unwrap();
    let tokens = [5u8, 10, 20, 40];
    let pjrt = exe.seq_logits(&tokens).unwrap();
    let rust = rust_model.seq_logits(&tokens).unwrap();
    for (a, b) in pjrt.iter().zip(&rust) {
        assert_allclose(a, b, 2e-3, 2e-3);
    }
}

#[test]
#[ignore = "needs `--features pjrt` + artifacts/ from `make artifacts`"]
fn batch8_artifact_matches_b1_per_row() {
    require_artifacts();
    let mut reg = ArtifactRegistry::open("artifacts").unwrap();
    let b1 = reg.model("model_target_fp32_b1").unwrap();
    let b8 = reg.model("model_target_fp32_b8").unwrap();
    let mut rng = Rng::new(7);
    let mut tokens = vec![0i32; 8 * 64];
    for t in tokens.iter_mut() {
        *t = rng.below(64) as i32;
    }
    let big = b8.run(&tokens).unwrap();
    for row in [0usize, 3, 7] {
        let single = b1.run(&tokens[row * 64..(row + 1) * 64]).unwrap();
        assert_allclose(
            &big[row * 64 * 256..(row + 1) * 64 * 256],
            &single,
            2e-3,
            2e-3,
        );
    }
}

/// Without the `pjrt` feature the runtime must refuse to open, not
/// pretend to work — guards against a silent-skip regression.
#[cfg(not(feature = "pjrt"))]
#[test]
fn registry_fails_loudly_without_pjrt_feature() {
    let err = ArtifactRegistry::open("artifacts").err();
    let err = err.expect("stub runtime must not succeed");
    assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
}
