//! Property tests for the sharded multi-worker scheduler
//! (`server::WorkerPool`): for every worker count the work-stealing pool
//! must stay *observationally identical* to sequential decoding on
//! per-request outputs, keep every worker inside its KV-budget share,
//! serve every request of randomized bursty traces exactly once, and
//! never make time-to-first-token worse than the single-worker scheduler
//! on the same trace. Failures reproduce deterministically via the seeded
//! harness in `angelslim::util::testing`.

use angelslim::data::{RequestGen, TokenRequest};
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_draft, fixture_target, FixtureSpec};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, check, fixture_requests,
    projected_greedy_bytes as projected_greedy, retry_timing,
};
use angelslim::util::Rng;

/// Seeded bursty trace (mixed short/long generations, near-simultaneous
/// arrivals inside each burst) — the workload sharding is for.
fn bursty(corpus: &[u8], seed: u64, bursts: usize, per_burst: usize) -> Vec<TokenRequest> {
    let mut gen = RequestGen::new(corpus.to_vec(), seed);
    gen.prompt_len = 8;
    gen.take_bursty(bursts, per_burst, 0.05, 4, 14)
}

#[test]
fn sharded_outputs_bit_identical_to_sequential_greedy() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 41);
    let target = fixture_target(5);
    let reqs = || fixture_requests(&corpus, 10, 12);

    let sequential = ServingEngine::serve::<Transformer, _>(reqs(), &target, None, 0).unwrap();
    for workers in [1, 2, 4] {
        let sharded = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs(),
            &target,
            None,
            &ServeCfg::continuous(4).with_workers(workers),
            0,
        )
        .unwrap();
        assert_eq!(sharded.workers(), workers);
        assert_serving_contracts(&sharded, 10, 0);
        assert_outputs_match(
            &sequential,
            &sharded,
            &format!("greedy workers={workers} vs sequential"),
        );
    }
}

#[test]
fn sharded_outputs_bit_identical_to_sequential_speculative() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 43);
    let target = fixture_target(3);
    let draft = fixture_draft(3);
    let reqs = || fixture_requests(&corpus, 8, 12);

    let sequential = ServingEngine::serve(reqs(), &target, Some((&draft, 3)), 0).unwrap();
    for workers in [1, 2, 4] {
        let sharded = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &ServeCfg::continuous(4).with_workers(workers),
            0,
        )
        .unwrap();
        assert_serving_contracts(&sharded, 8, 0);
        assert_outputs_match(
            &sequential,
            &sharded,
            &format!("speculative workers={workers} vs sequential"),
        );
        // the verify schedule per request is interleaving-independent, so
        // speculation bookkeeping must agree too
        assert_eq!(sequential.proposed, sharded.proposed, "workers={workers}");
        assert_eq!(sequential.accepted, sharded.accepted, "workers={workers}");
        assert!(sharded.mean_al > 1.2, "workers={workers} AL {}", sharded.mean_al);
    }
}

#[test]
fn per_worker_live_kv_never_exceeds_worker_share() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 47);
    let target = fixture_target(5);
    let reqs = || fixture_requests(&corpus, 12, 12);
    let worst = reqs().iter().map(|r| projected_greedy(&target, r)).max().unwrap();

    for workers in [2, 4] {
        // each worker's share seats ~2 requests, so budget pressure is
        // real on every worker while no request needs the safety valve
        let cfg = ServeCfg::continuous(8)
            .with_workers(workers)
            .with_budget(workers * (2 * worst + 64));
        let shares = cfg.per_worker_budgets();
        let report = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs(),
            &target,
            None,
            &cfg,
            0,
        )
        .unwrap();
        assert_serving_contracts(&report, 12, cfg.kv_budget_bytes);
        assert_eq!(report.worker_peak_kv_bytes.len(), workers);
        assert!(
            report.worker_peak_kv_bytes.iter().any(|&p| p > 0),
            "fixture sessions hold real KV bytes"
        );
        for (w, peak) in report.worker_peak_kv_bytes.iter().enumerate() {
            assert!(
                *peak <= shares[w],
                "workers={workers}: worker {w} peak {peak} exceeded share {}",
                shares[w]
            );
        }
    }
}

/// Randomized seeded bursty traces, randomized worker counts and budgets:
/// every request completes exactly once across the pool (no duplicates,
/// no drops), outputs stay bit-identical to sequential decoding, and
/// every worker stays inside its KV-budget share.
#[test]
fn randomized_bursty_traces_serve_exactly_once_across_workers() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 4_096, 53);
    let target = fixture_target(7);
    check(6, |rng: &mut Rng| {
        let bursts = 1 + rng.below(3);
        let per_burst = 2 + rng.below(4);
        let n = bursts * per_burst;
        let trace_seed = rng.next_u64();
        let workers = 1 + rng.below(4);
        let trace = || bursty(&corpus, trace_seed, bursts, per_burst);
        let worst = trace().iter().map(|r| projected_greedy(&target, r)).max().unwrap();
        // every worker's share seats the worst request at least once
        let cfg = ServeCfg::continuous(1 + rng.below(4))
            .with_workers(workers)
            .with_budget(workers * worst * (1 + rng.below(2)));
        let shares = cfg.per_worker_budgets();

        let sequential =
            ServingEngine::serve::<Transformer, _>(trace(), &target, None, 0).unwrap();
        let sharded = ServingEngine::serve_scheduled::<Transformer, _>(
            trace(),
            &target,
            None,
            &cfg,
            0,
        )
        .unwrap();
        assert_serving_contracts(&sharded, n, cfg.kv_budget_bytes);
        assert_outputs_match(&sequential, &sharded, "randomized sharded vs sequential");
        for (w, peak) in sharded.worker_peak_kv_bytes.iter().enumerate() {
            assert!(
                *peak <= shares[w],
                "worker {w} peak {peak} exceeded share {}",
                shares[w]
            );
        }
    });
}

/// Adding workers must not make time-to-first-token worse: on a bursty
/// trace the pool's extra capacity admits queued requests earlier. The
/// comparison uses the *median* TTFT — a single OS preemption inflates a
/// few requests' measured rounds but barely moves the p50 over 18
/// requests, whereas the queueing signal (whole decode drains at 1
/// worker) dominates it — and timing-noise runs are still retried
/// through the shared `retry_timing` harness the serving benches use.
#[test]
fn multi_worker_ttft_not_worse_than_single_worker() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 4_096, 59);
    let target = fixture_target(3);
    let trace = || bursty(&corpus, 71, 3, 6);

    retry_timing(5, || {
        let one = ServingEngine::serve_scheduled::<Transformer, _>(
            trace(),
            &target,
            None,
            &ServeCfg::continuous(4),
            0,
        )
        .unwrap();
        for workers in [2, 4] {
            let sharded = ServingEngine::serve_scheduled::<Transformer, _>(
                trace(),
                &target,
                None,
                &ServeCfg::continuous(4).with_workers(workers),
                0,
            )
            .unwrap();
            assert_outputs_match(&one, &sharded, &format!("ttft run workers={workers}"));
            let m1 = one.ttft_summary().p50;
            let mw = sharded.ttft_summary().p50;
            if mw > m1 {
                return Err(format!(
                    "workers={workers}: median TTFT {mw:.4}ms worse than single-worker {m1:.4}ms"
                ));
            }
        }
        Ok(())
    });
}
