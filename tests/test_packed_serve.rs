//! Quantized serving equivalence — the packed execution path's
//! correctness anchor: serving a packed model must be indistinguishable
//! from serving the dequantized-f32 model holding exactly the values the
//! packed codes decode to. Prefill/forward are compared bitwise (the
//! fused-dequant matmul preserves f32 accumulation order); decode and the
//! continuous-batching scheduler are compared at token level, which is
//! bit-identity at the ServeReport contract (outputs are token bytes).
//!
//! Also exercises the compress→export→serve artifact contract end to end:
//! the shipped `configs/pipeline_packed_serve_fixture.yaml` pipeline runs
//! hermetically, and the artifact it writes serves bit-identically both
//! through `packed_store::load_packed` and the `packed-artifact` model
//! factory.

use angelslim::config::SlimConfig;
use angelslim::coordinator::{CompressEngine, ModelFactory};
use angelslim::models::{packed_store, AttnOverride, PackedLinear, Transformer};
use angelslim::quant::packing::PackFormat;
use angelslim::server::{ServeCfg, ServingEngine};
use angelslim::tensor::ops::argmax;
use angelslim::util::fixtures::{fixture_corpus, FixtureSpec};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, fixture_requests, packed_twins,
};

const FORMATS: [PackFormat; 4] = [
    PackFormat::Int4,
    PackFormat::TwoBit,
    PackFormat::Ternary167,
    PackFormat::Sherry125,
];

#[test]
fn packed_forward_and_prefill_bit_identical_to_dequantized_twin() {
    let spec = FixtureSpec::default();
    let toks = fixture_corpus(&spec, 24, 17);
    for fmt in FORMATS {
        let (packed, dense) = packed_twins(fmt, 16, 9);
        let name = fmt.name();

        let lp = packed.forward(&toks, &AttnOverride::None);
        let ld = dense.forward(&toks, &AttnOverride::None);
        assert_eq!(lp.data, ld.data, "{name}: forward logits drifted bitwise");

        let mut cp = packed.new_cache();
        let mut cd = dense.new_cache();
        let rp = packed.prefill(&mut cp, &toks);
        let rd = dense.prefill(&mut cd, &toks);
        assert_eq!(rp.data, rd.data, "{name}: prefill logits drifted bitwise");
    }
}

#[test]
fn packed_greedy_decode_token_identical_through_kv_cache() {
    let spec = FixtureSpec::default();
    let prompt = fixture_corpus(&spec, 8, 23);
    for fmt in FORMATS {
        let (packed, dense) = packed_twins(fmt, 16, 4);
        let name = fmt.name();

        let generate = |m: &Transformer| -> Vec<u8> {
            let mut cache = m.new_cache();
            let rows = m.prefill(&mut cache, &prompt);
            let mut last = rows.row(rows.rows() - 1).to_vec();
            let mut out = Vec::new();
            for _ in 0..24 {
                let next = argmax(&last) as u8;
                out.push(next);
                last = m.decode_step(&mut cache, next);
            }
            out
        };
        assert_eq!(
            generate(&packed),
            generate(&dense),
            "{name}: packed decode_step diverged from the dequantized twin"
        );
    }
}

#[test]
fn packed_scheduler_serving_bit_identical_to_dequantized_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 31);
    for fmt in FORMATS {
        let (packed, dense) = packed_twins(fmt, 16, 6);
        let name = fmt.name();
        let reqs = || fixture_requests(&corpus, 6, 10);

        let dense_report =
            ServingEngine::serve::<Transformer, _>(reqs(), &dense, None, 0).unwrap();
        let packed_report = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs(),
            &packed,
            None,
            &ServeCfg::continuous(3),
            0,
        )
        .unwrap();
        assert_serving_contracts(&packed_report, 6, 0);
        assert_outputs_match(
            &dense_report,
            &packed_report,
            &format!("{name}: packed continuous vs dense sequential"),
        );
    }
}

/// The full compress→export→serve loop on the shipped pipeline config:
/// run the mixed-precision pack pipeline hermetically, reload the
/// exported artifact (both directly and through the `packed-artifact`
/// model factory), and demand token-identical serving everywhere.
#[test]
fn exported_packed_artifact_serves_bit_identically() {
    let path = "configs/pipeline_packed_serve_fixture.yaml";
    let engine = CompressEngine::from_file(path).unwrap();
    let save_path = engine.cfg.global.save_path.clone();
    let _ = std::fs::remove_dir_all(&save_path);
    let (report, ctx) = engine.run_with_context().unwrap();

    assert_eq!(report.stages.len(), 3, "{report:?}");
    // stage ratios charge still-f32 layers honestly, so the first pack
    // stage (attention+head int4, MLP still f32) shrinks but stays well
    // above the final mixed-precision ratio the second stage reaches
    let (s0, s1) = (&report.stages[0], &report.stages[1]);
    assert_eq!(s0.kind, "quantization", "{s0:?}");
    assert_eq!(s1.kind, "quantization", "{s1:?}");
    assert!(s0.size_ratio < 1.0, "int4 stage must shrink storage: {s0:?}");
    assert!(s1.size_ratio < s0.size_ratio, "second pack stage shrinks further: {s1:?}");
    assert!(s1.size_ratio < 0.2, "mixed int4+2bit lands far below f32: {s1:?}");
    assert!(report.overall_size_ratio() < 0.2, "{report:?}");
    assert!(
        report.stages[2].notes.iter().any(|n| n.contains("packed artifact")),
        "{report:?}"
    );

    let compressed = ctx.into_model().expect("pipeline surrenders the packed model");
    // the shipped config is mixed precision: int4 attention, 2bit MLP
    for (weight, want) in [
        ("layer0.wq", PackFormat::Int4),
        ("head", PackFormat::Int4),
        ("layer0.w_gate", PackFormat::TwoBit),
        ("layer1.w_down", PackFormat::TwoBit),
    ] {
        let fmt = compressed
            .named_weights()
            .into_iter()
            .find(|(n, _)| n == weight)
            .map(|(_, w)| w.format())
            .unwrap();
        assert_eq!(fmt, want, "{weight}");
    }

    let loaded = packed_store::load_packed(&save_path).unwrap();
    let dense = compressed.dequantized();
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 41);
    let reqs = || fixture_requests(&corpus, 6, 10);

    let dense_report = ServingEngine::serve::<Transformer, _>(reqs(), &dense, None, 0).unwrap();
    let loaded_report = ServingEngine::serve_scheduled::<Transformer, _>(
        reqs(),
        &loaded,
        None,
        &ServeCfg::continuous(4),
        0,
    )
    .unwrap();
    assert_serving_contracts(&loaded_report, 6, 0);
    assert_outputs_match(&dense_report, &loaded_report, "exported artifact vs dequantized f32");

    // the same artifact through the serve-side model factory
    let mut cfg = SlimConfig::from_file(path).unwrap();
    cfg.model.name = "packed-artifact".into();
    cfg.model.artifacts_dir = save_path.clone();
    let via_factory = ModelFactory::load(&cfg).unwrap();
    let factory_report =
        ServingEngine::serve::<Transformer, _>(reqs(), &via_factory, None, 0).unwrap();
    assert_outputs_match(&dense_report, &factory_report, "factory-loaded artifact vs f32");
}

/// Repacking guard: a second pack stage whose selector overlaps an
/// already-packed weight must fail loudly instead of quantizing twice.
#[test]
fn overlapping_pack_stages_fail_loudly() {
    let src = "global:\n  save_path: target/test-output/packed_overlap\n\
               model:\n  name: tiny-fixture\n\
               pipeline:\n  - pass: pack\n    format: int4\n    group_size: 16\n\
               \x20 - pass: pack\n    format: 2bit\n    include: [w_gate]\n\
               dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n";
    let engine = CompressEngine::new(SlimConfig::from_str(src).unwrap()).unwrap();
    let err = engine.run().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("already") && msg.contains("packed"), "{msg}");
}

/// `PackedLinear` storage accounting feeds the stage size_ratio: packing
/// must report honestly smaller stored bytes per format.
#[test]
fn packed_twins_shrink_stored_bytes_per_format() {
    for fmt in FORMATS {
        let (packed, dense) = packed_twins(fmt, 16, 2);
        assert!(
            packed.stored_weight_bytes() < dense.stored_weight_bytes() / 4,
            "{}: {} vs {}",
            fmt.name(),
            packed.stored_weight_bytes(),
            dense.stored_weight_bytes()
        );
        // and the enum reports the format it holds
        assert!(packed.named_weights().iter().all(|(_, w)| w.format() == fmt));
        assert!(matches!(dense.head, PackedLinear::F32(_)));
    }
}
