//! Determinism contracts for the RNG (every synthetic workload in the
//! repo is seeded through it) and regression tests for `Summary` on
//! degenerate inputs.

use angelslim::util::{Rng, Summary};

#[test]
fn rng_same_seed_same_stream() {
    let mut a = Rng::new(0xDEAD_BEEF);
    let mut b = Rng::new(0xDEAD_BEEF);
    for _ in 0..1_000 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
    // and across every derived sampler
    let mut a = Rng::new(42);
    let mut b = Rng::new(42);
    for _ in 0..200 {
        assert_eq!(a.f32(), b.f32());
        assert_eq!(a.f64(), b.f64());
        assert_eq!(a.normal(), b.normal());
        assert_eq!(a.below(17), b.below(17));
        assert_eq!(a.bool(0.3), b.bool(0.3));
    }
    let mut xs: Vec<u32> = (0..64).collect();
    let mut ys = xs.clone();
    a.shuffle(&mut xs);
    b.shuffle(&mut ys);
    assert_eq!(xs, ys);
    assert_eq!(a.choose(50, 10), b.choose(50, 10));
}

#[test]
fn rng_different_seeds_diverge() {
    let a: Vec<u64> = {
        let mut r = Rng::new(1);
        (0..16).map(|_| r.next_u64()).collect()
    };
    let b: Vec<u64> = {
        let mut r = Rng::new(2);
        (0..16).map(|_| r.next_u64()).collect()
    };
    assert_ne!(a, b);
    // nearby seeds decorrelate (splitmix expansion), so no element-wise
    // collisions either
    let collisions = a.iter().zip(&b).filter(|(x, y)| x == y).count();
    assert_eq!(collisions, 0);
}

#[test]
fn rng_clone_forks_identical_stream() {
    let mut a = Rng::new(7);
    a.next_u64();
    let mut b = a.clone();
    for _ in 0..100 {
        assert_eq!(a.next_u64(), b.next_u64());
    }
}

#[test]
fn summary_empty_input_is_all_zero_defaults() {
    let s = Summary::of(&[]);
    assert_eq!(s.n, 0);
    assert_eq!(s.mean, 0.0);
    assert_eq!(s.std, 0.0);
    assert_eq!(s.min, 0.0);
    assert_eq!(s.max, 0.0);
    assert_eq!(s.p50, 0.0);
    assert_eq!(s.p90, 0.0);
    assert_eq!(s.p99, 0.0);
}

#[test]
fn summary_single_element_regression() {
    let s = Summary::of(&[3.25]);
    assert_eq!(s.n, 1);
    assert_eq!(s.mean, 3.25);
    assert_eq!(s.std, 0.0);
    assert_eq!(s.min, 3.25);
    assert_eq!(s.max, 3.25);
    // every percentile of a single sample is that sample
    assert_eq!(s.p50, 3.25);
    assert_eq!(s.p90, 3.25);
    assert_eq!(s.p99, 3.25);
}

#[test]
fn summary_two_elements_and_ordering() {
    let s = Summary::of(&[10.0, 2.0]);
    assert_eq!(s.n, 2);
    assert_eq!(s.min, 2.0);
    assert_eq!(s.max, 10.0);
    assert!((s.mean - 6.0).abs() < 1e-12);
    assert!(s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
}

#[test]
fn summary_percentiles_monotone_on_random_input() {
    let mut rng = Rng::new(5);
    let xs: Vec<f64> = (0..500).map(|_| rng.f64() * 100.0).collect();
    let s = Summary::of(&xs);
    assert!(s.min <= s.p50 && s.p50 <= s.p90 && s.p90 <= s.p99 && s.p99 <= s.max);
    assert!(s.std > 0.0);
}
