//! Property tests for the continuous-batching scheduler: randomized and
//! fixture-driven traces must uphold the serving contracts — per-request
//! outputs bit-identical to sequential decoding, live KV bytes within the
//! admission budget, and completion of every request (no starvation) even
//! under tight budgets. Failures reproduce deterministically via the
//! seeded harness in `angelslim::util::testing`, and the trace builder /
//! equivalence assertions live there too, shared with the serving benches
//! and `tests/test_sharded_props.rs`.

use angelslim::data::TokenRequest;
use angelslim::models::Transformer;
use angelslim::server::{ServeCfg, ServingEngine};
use angelslim::util::fixtures::{fixture_corpus, fixture_draft, fixture_target, FixtureSpec};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, check, fixture_requests,
    projected_greedy_bytes as projected_greedy,
};
use angelslim::util::Rng;

#[test]
fn continuous_outputs_bit_identical_to_sequential_greedy() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 13);
    let target = fixture_target(5);
    let reqs = || fixture_requests(&corpus, 9, 12);

    let sequential = ServingEngine::serve::<Transformer, _>(reqs(), &target, None, 0).unwrap();
    for max_in_flight in [2, 4, 9] {
        let continuous = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs(),
            &target,
            None,
            &ServeCfg::continuous(max_in_flight),
            0,
        )
        .unwrap();
        assert_serving_contracts(&continuous, 9, 0);
        assert_outputs_match(
            &sequential,
            &continuous,
            &format!("continuous (max_in_flight {max_in_flight}) vs sequential"),
        );
    }
}

#[test]
fn continuous_outputs_bit_identical_to_sequential_speculative() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 29);
    let target = fixture_target(3);
    let draft = fixture_draft(3);
    let reqs = || fixture_requests(&corpus, 8, 12);

    let sequential = ServingEngine::serve(reqs(), &target, Some((&draft, 3)), 0).unwrap();
    let continuous = ServingEngine::serve_scheduled(
        reqs(),
        &target,
        Some((&draft, 3)),
        &ServeCfg::continuous(4),
        0,
    )
    .unwrap();
    assert_serving_contracts(&continuous, 8, 0);
    assert_outputs_match(&sequential, &continuous, "continuous spec vs sequential spec");
    assert!(sequential.mean_al > 1.2, "AL {}", sequential.mean_al);
    assert!(continuous.mean_al > 1.2, "AL {}", continuous.mean_al);
    // aligned draft: the target accepts most proposals on either path
    assert!(continuous.acceptance_rate() > 0.3, "{}", continuous.acceptance_rate());
    assert_eq!(sequential.proposed, continuous.proposed);
    assert_eq!(sequential.accepted, continuous.accepted);
}

#[test]
fn live_kv_bytes_never_exceed_budget() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 7);
    let target = fixture_target(5);
    let reqs = fixture_requests(&corpus, 9, 12);
    let worst = reqs.iter().map(|r| projected_greedy(&target, r)).max().unwrap();
    // room for ~2 concurrent requests, far below max_in_flight's 8
    let budget = 2 * worst + 64;
    let report = ServingEngine::serve_scheduled::<Transformer, _>(
        reqs,
        &target,
        None,
        &ServeCfg::continuous(8).with_budget(budget),
        0,
    )
    .unwrap();
    assert!(report.peak_kv_bytes > 0, "fixture sessions hold real KV bytes");
    assert_serving_contracts(&report, 9, budget);
}

#[test]
fn tight_budget_completes_every_request_with_correct_outputs() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 17);
    let target = fixture_target(5);
    let reqs = || fixture_requests(&corpus, 8, 10);
    let worst = reqs().iter().map(|r| projected_greedy(&target, r)).max().unwrap();
    // tightest non-degenerate budget: exactly one request at a time
    let budget = worst;
    let sequential = ServingEngine::serve::<Transformer, _>(reqs(), &target, None, 0).unwrap();
    let tight = ServingEngine::serve_scheduled::<Transformer, _>(
        reqs(),
        &target,
        None,
        &ServeCfg::continuous(8).with_budget(budget),
        0,
    )
    .unwrap();
    assert_serving_contracts(&tight, 8, budget);
    assert_outputs_match(&sequential, &tight, "tight budget vs sequential");
}

#[test]
fn speculative_budget_covers_draft_and_target_sessions() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 23);
    let target = fixture_target(3);
    let draft = fixture_draft(3);
    let reqs = fixture_requests(&corpus, 6, 10);
    let bpt = target.cfg.kv_bytes_per_token() + draft.cfg.kv_bytes_per_token();
    let worst = reqs
        .iter()
        .map(|r| (r.prompt.len() + r.max_new_tokens).min(target.cfg.max_t) * bpt)
        .max()
        .unwrap();
    let budget = 2 * worst;
    let report = ServingEngine::serve_scheduled(
        reqs,
        &target,
        Some((&draft, 3)),
        &ServeCfg::continuous(6).with_budget(budget),
        0,
    )
    .unwrap();
    assert_serving_contracts(&report, 6, budget);
}

/// Randomized traces and configurations: every request is served exactly
/// once with outputs identical to sequential decoding, TTFT never lands
/// after completion, and the KV budget holds whenever it admits at least
/// one request.
#[test]
fn randomized_traces_uphold_serving_contracts() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 4_096, 31);
    let target = fixture_target(7);
    check(8, |rng: &mut Rng| {
        let n = 4 + rng.below(8);
        let mut t = 0.0f64;
        let reqs: Vec<TokenRequest> = (0..n)
            .map(|i| {
                t += rng.f64() * 2.0;
                let start = rng.below(corpus.len() - 12);
                TokenRequest {
                    id: i as u64,
                    prompt: corpus[start..start + 4 + rng.below(8)].to_vec(),
                    max_new_tokens: 1 + rng.below(10),
                    arrival_ms: t,
                    deadline_ms: None,
                    class: Default::default(),
                }
            })
            .collect();
        let worst = reqs.iter().map(|r| projected_greedy(&target, r)).max().unwrap();
        let budget = worst * (1 + rng.below(3));
        let max_in_flight = 1 + rng.below(6);

        let sequential =
            ServingEngine::serve::<Transformer, _>(reqs.clone(), &target, None, 0).unwrap();
        let continuous = ServingEngine::serve_scheduled::<Transformer, _>(
            reqs,
            &target,
            None,
            &ServeCfg::continuous(max_in_flight).with_budget(budget),
            0,
        )
        .unwrap();
        assert_serving_contracts(&continuous, n, budget);
        assert_outputs_match(&sequential, &continuous, "randomized continuous vs sequential");
    });
}
