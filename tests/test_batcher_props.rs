//! Property-style tests for the serving batcher: randomized request
//! streams driven through `Batcher::try_form` must uphold the three
//! serving contracts — the batch cap, the wait deadline, and FIFO order.
//! Failures reproduce deterministically via the seeded harness in
//! `angelslim::util::testing`.

use angelslim::data::TokenRequest;
use angelslim::server::{Batcher, BatcherCfg};
use angelslim::util::testing::check;
use angelslim::util::Rng;

fn req(id: u64, arrival_ms: f64) -> TokenRequest {
    TokenRequest { id, prompt: vec![1, 2, 3], max_new_tokens: 4, arrival_ms }
}

/// Drive one randomized scenario; calls `on_batch(now, batch_ids)` for
/// every formed batch and `on_wait(now, oldest_arrival)` whenever the
/// batcher declines to form one while requests are queued.
fn drive(
    rng: &mut Rng,
    cfg: BatcherCfg,
    mut on_batch: impl FnMut(f64, &[u64]),
    mut on_wait: impl FnMut(f64, f64),
) {
    let mut batcher = Batcher::new(cfg);
    // arrival times: nondecreasing with random gaps
    let n = 20 + rng.below(40);
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for _ in 0..n {
        t += rng.f64() * 6.0;
        arrivals.push(t);
    }

    let mut queued: std::collections::VecDeque<(u64, f64)> = Default::default();
    let mut next = 0usize;
    let mut clock = 0.0f64;
    while next < n || queued.front().is_some() {
        // admit all arrivals up to the clock
        while next < n && arrivals[next] <= clock {
            batcher.push(req(next as u64, arrivals[next]));
            queued.push_back((next as u64, arrivals[next]));
            next += 1;
        }
        match batcher.try_form(clock) {
            Some(batch) => {
                let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
                for _ in &ids {
                    queued.pop_front();
                }
                on_batch(clock, &ids);
            }
            None => {
                if let Some(&(_, oldest)) = queued.front() {
                    on_wait(clock, oldest);
                }
                clock += 0.25 + rng.f64() * 2.0;
            }
        }
        if next < n && queued.is_empty() {
            clock = clock.max(arrivals[next]);
        }
    }
}

fn random_cfg(rng: &mut Rng) -> BatcherCfg {
    BatcherCfg {
        max_batch: 1 + rng.below(9),
        max_wait_ms: 0.5 + rng.f64() * 12.0,
    }
}

#[test]
fn batch_size_never_exceeds_max() {
    check(24, |rng| {
        let cfg = random_cfg(rng);
        let max_batch = cfg.max_batch;
        drive(
            rng,
            cfg,
            |_, ids| {
                assert!(!ids.is_empty(), "formed an empty batch");
                assert!(ids.len() <= max_batch, "batch of {} > cap {max_batch}", ids.len());
            },
            |_, _| {},
        );
    });
}

#[test]
fn oldest_request_never_waits_past_deadline_unserved() {
    check(24, |rng| {
        let cfg = random_cfg(rng);
        let max_wait = cfg.max_wait_ms;
        drive(
            rng,
            cfg,
            |_, _| {},
            |now, oldest_arrival| {
                // declining to form a batch is only legal while the oldest
                // queued request is still inside the wait window
                let waited = now - oldest_arrival;
                assert!(
                    waited < max_wait,
                    "oldest waited {waited:.2}ms with deadline {max_wait:.2}ms and no batch"
                );
            },
        );
    });
}

#[test]
fn fifo_order_preserved_across_batches() {
    check(24, |rng| {
        let cfg = random_cfg(rng);
        let mut expected_next = 0u64;
        drive(
            rng,
            cfg,
            |_, ids| {
                for &id in ids {
                    assert_eq!(id, expected_next, "out-of-order drain: {ids:?}");
                    expected_next += 1;
                }
            },
            |_, _| {},
        );
    });
}

#[test]
fn all_requests_eventually_served() {
    check(24, |rng| {
        let cfg = random_cfg(rng);
        let mut served = 0usize;
        drive(rng, cfg, |_, ids| served += ids.len(), |_, _| {});
        assert!(served >= 20, "only {served} requests served");
    });
}
