//! Hermetic end-to-end pipeline tests — the whole paper flow (calibrate →
//! quantize → perplexity eval → speculative decode → batch + serve →
//! sparse-attention / token-prune invariants) driven through a tiny
//! deterministic in-memory fixture transformer. No `artifacts/` on disk,
//! no PJRT, no python build: this is the gate `cargo test -q` runs on a
//! clean checkout.
//!
//! Fixture ↔ paper mapping (see rust/src/util/fixtures.rs):
//!   * `fixture_target` / `fixture_draft` — the target/draft model pair
//!     (draft encodes the same rule: the Eagle3 "training-aligned" setup)
//!   * `fixture_corpus`                  — the calibration/eval dataset
//!   * PTQ ladder fp8 → int4 → seq2 → ternary — §2's quantization suite
//!   * SpecDecoder vs VanillaDecoder     — §3's lossless speculative loop
//!   * Scheduler + ServingEngine         — the deployment layer
//!   * SparseAlgo masks on captured Q/K/V — §4.1's pattern estimators

use angelslim::config::SlimConfig;
use angelslim::coordinator::CompressEngine;
use angelslim::data::RequestGen;
use angelslim::eval::corpus_nll;
use angelslim::models::{AttnOverride, Transformer};
use angelslim::quant::{
    AffineQuantizer, Fp8WeightQuantizer, Seq2Quantizer, TernaryQuantizer,
};
use angelslim::server::ServingEngine;
use angelslim::sparse_attn::SparseAlgo;
use angelslim::spec_decode::{SpecDecoder, VanillaDecoder};
use angelslim::util::fixtures::{
    fixture_corpus, fixture_draft, fixture_target, fixture_transformer, FixtureSpec,
};
use angelslim::util::Rng;

fn nll_of(m: &Transformer, corpus: &[u8]) -> f64 {
    corpus_nll(m, corpus, 40, 6).unwrap()
}

/// The paper-shaped PTQ ladder on one model: quantize every linear with
/// each format and check perplexity on the rule corpus orders the formats
/// by coarseness (fp32 ≈ fp8 ≤ int4, with 2-bit PTQ degrading and ternary
/// PTQ collapsing).
#[test]
fn quantization_ladder_orders_by_coarseness() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 11);
    let base_model = fixture_target(1);
    let base = nll_of(&base_model, &corpus);
    assert!(base < 1.0, "fixture failed to encode the rule: NLL {base}");

    let quantized_nll = |q: &dyn angelslim::quant::WeightQuantizer| -> f64 {
        let mut m = base_model.clone();
        m.apply_quantizer(q);
        nll_of(&m, &corpus)
    };
    let fp8 = quantized_nll(&Fp8WeightQuantizer);
    let int4 = quantized_nll(&AffineQuantizer::int4_group32());
    let seq2 = quantized_nll(&Seq2Quantizer::tuned(32));
    let tern = quantized_nll(&TernaryQuantizer::default());

    // fp8 is near-lossless on this weight distribution
    assert!((fp8 - base).abs() < 0.15, "fp8 {fp8} vs fp32 {base}");
    // int4 group-32 stays close to the reference
    assert!(int4 < base + 0.6, "int4 {int4} vs fp32 {base}");
    // ternary's per-row alpha crushes the planted signal — visible collapse
    assert!(tern > base + 0.4, "ternary {tern} should collapse vs fp32 {base}");
    assert!(fp8 < tern && int4 < tern, "fp8 {fp8} / int4 {int4} / ternary {tern}");
    // 2-bit SEQ amplifies the noise floor (no zero level) so it must sit
    // strictly between int4 and the ternary collapse — the paper ordering
    assert!(base <= seq2 + 0.1, "fp32 {base} vs seq2 {seq2}");
    assert!(int4 < seq2, "int4 {int4} must beat seq2 {seq2}");
    assert!(seq2 < tern - 0.3, "seq2 {seq2} vs ternary {tern}");
}

/// Greedy speculative decoding must be output-identical to vanilla
/// decoding whether the draft agrees (high acceptance) or not.
#[test]
fn speculative_decode_is_lossless_and_accepts_aligned_draft() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 5);
    let target = fixture_target(2);
    let aligned_draft = fixture_draft(2);
    let prompt = &corpus[64..72];
    let mut rng = Rng::new(0);

    let (vseq, vstats) = VanillaDecoder::new(&target)
        .generate(prompt, 24, &mut rng)
        .unwrap();
    let (sseq, sstats) = SpecDecoder::new(&aligned_draft, &target, 3)
        .generate(prompt, 24, &mut rng)
        .unwrap();
    assert_eq!(vseq, sseq, "greedy spec decode must preserve outputs");
    assert_eq!(vstats.generated, sstats.generated);
    assert!(sstats.al() > 1.5, "aligned draft AL {}", sstats.al());
    assert!(sstats.acceptance_rate() > 0.3, "{}", sstats.acceptance_rate());
    assert!(sstats.steps < vstats.steps, "spec must need fewer target steps");

    // a draft encoding a DIFFERENT rule must not change outputs either
    let wrong_draft = fixture_transformer(&FixtureSpec {
        shift: 9,
        seed: 77,
        ..FixtureSpec::default()
    });
    let (wseq, wstats) = SpecDecoder::new(&wrong_draft, &target, 3)
        .generate(prompt, 24, &mut rng)
        .unwrap();
    assert_eq!(vseq, wseq, "correctness must not depend on draft quality");
    assert!(wstats.acceptance_rate() < 0.5, "{}", wstats.acceptance_rate());
}

/// The serving layer end-to-end: request stream → scheduler → decode loop
/// → report. Vanilla and speculative serving must complete every request
/// with identical outputs; speculative serving must commit >1 token per
/// target step on the aligned draft.
#[test]
fn serving_engine_end_to_end_report_is_sane() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 9);
    let target = fixture_target(3);
    let draft = fixture_draft(3);

    let make_requests = || {
        let mut gen = RequestGen::new(corpus.clone(), 42);
        gen.prompt_len = 8;
        gen.max_new_tokens = 12;
        gen.take(10)
    };

    let vanilla =
        ServingEngine::serve::<Transformer, _>(make_requests(), &target, None, 0).unwrap();
    let spec_report =
        ServingEngine::serve(make_requests(), &target, Some((&draft, 3)), 0).unwrap();

    for report in [&vanilla, &spec_report] {
        assert_eq!(report.completed.len(), 10);
        assert!(report.completed.iter().all(|c| c.generated == 12), "budget respected");
        assert!(report.total_tokens == 120);
        assert!(report.tps() > 0.0);
        let lat = report.latency_summary();
        let ttft = report.ttft_summary();
        assert!(lat.p50 <= lat.p90 + 1e-9 && lat.p90 <= lat.max + 1e-9);
        assert!(ttft.min >= 0.0 && ttft.max >= ttft.min);
        assert!(
            report.completed.iter().all(|c| c.ttft_ms <= c.total_ms + 1e-9),
            "first token cannot land after completion"
        );
    }
    assert_eq!(vanilla.mean_al, 1.0);
    assert!(spec_report.mean_al > 1.5, "AL {}", spec_report.mean_al);
    for (a, b) in vanilla.completed.iter().zip(&spec_report.completed) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.output, b.output, "spec serving changed request {}", a.id);
    }
}

/// Config-file pipeline: YAML → CompressEngine over the fixture model and
/// fixture dataset, for a calibrated (GPTQ) job under the low-memory
/// ledger — the §2.3 "single-GPU calibration" accounting.
#[test]
fn yaml_gptq_job_with_low_memory_ledger() {
    let cfg = |budget: usize| {
        format!(
            "global:\n  save_path: target/test-output/hermetic\n  seed: 7\n\
             model:\n  name: tiny-fixture\n\
             compression:\n  method: quantization\n  quantization:\n    algo: gptq\n    low_memory_budget_layers: {budget}\n\
             dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n"
        )
    };
    let run_stage = |src: &str| {
        let r = CompressEngine::new(SlimConfig::from_str(src).unwrap())
            .unwrap()
            .run()
            .unwrap();
        r.stages.into_iter().next().unwrap()
    };
    let full = run_stage(&cfg(0));
    let lo = run_stage(&cfg(1));
    assert!(full.metric_before < 1.0, "{full:?}");
    assert!(full.metric_after < full.metric_before + 0.8, "gptq must not collapse: {full:?}");
    assert!(lo.peak_calib_bytes < full.peak_calib_bytes, "{lo:?} vs {full:?}");
    assert!((lo.metric_after - full.metric_after).abs() < 1e-6, "streaming must not change math");
    assert!(full.notes.iter().any(|n| n.contains("calibration peak")), "{full:?}");
}

/// Sparse-attention pattern estimators on the fixture model's own Q/K/V:
/// causality, forced diagonal, budget-bounded density, and a masked
/// forward that stays finite.
#[test]
fn sparse_masks_uphold_invariants_on_fixture_qkv() {
    let spec = FixtureSpec::default();
    let model = fixture_target(4);
    let corpus = fixture_corpus(&spec, 256, 3);
    let tokens = &corpus[..40];
    let qkv = model.capture_qk(tokens);
    let (q, k, v) = &qkv[0];

    for algo in [
        SparseAlgo::AShape,
        SparseAlgo::TriShape,
        SparseAlgo::Dilated,
        SparseAlgo::Strided,
        SparseAlgo::MInference,
        SparseAlgo::XAttention,
        SparseAlgo::FlexPrefill,
        SparseAlgo::Stem,
    ] {
        let mask = algo.mask(q, k, v, 8, 0.4);
        assert_eq!(mask.t, 40, "{}", algo.name());
        for qb in 0..mask.nb {
            assert!(mask.get(qb, qb), "{} must keep the diagonal", algo.name());
            for kb in qb + 1..mask.nb {
                assert!(!mask.get(qb, kb), "{} kept an acausal block", algo.name());
            }
        }
        let d = mask.density();
        assert!(d > 0.0 && d <= 1.0, "{} density {d}", algo.name());

        let token_mask = mask.to_token_mask();
        assert_eq!(token_mask.len(), 40 * 40);
        let logits = model.forward(tokens, &AttnOverride::Mask(token_mask));
        assert!(
            logits.data.iter().all(|x| x.is_finite()),
            "{} produced non-finite logits",
            algo.name()
        );
    }
}

/// Shipped-config smoke: the fixture config file drives the engine from
/// disk exactly like `angelslim compress <path>` would.
#[test]
fn quant_int4_fixture_config_file_runs() {
    let engine = CompressEngine::from_file("configs/quant_int4_fixture.yaml").unwrap();
    let report = engine.run().unwrap();
    assert_eq!(report.stages.len(), 1, "legacy config desugars to one stage");
    let r = &report.stages[0];
    assert_eq!(r.kind, "quantization");
    assert_eq!(r.pass, "int4");
    assert!(r.metric_before < 1.0, "{r:?}");
    assert!(r.metric_after < r.metric_before + 0.6, "{r:?}");
    assert!((r.compression - 5.0).abs() < 1e-9);
    assert!((report.overall_size_ratio() - 5.0 / 32.0).abs() < 1e-12);
}
