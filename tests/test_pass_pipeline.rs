//! The composable CompressionPass pipeline, end to end and hermetic:
//!
//! * pipeline-equivalence property — a one-stage `pipeline:` config is
//!   bit-identical (model weights AND report numbers) to the legacy
//!   `compression.method` form, for a representative pass from each
//!   method family (quant RTN, quant calibrated, token prune, sparse
//!   attention);
//! * the shipped multi-stage fixture configs (`smooth → gptq → eval`,
//!   `token_prune → int4 → eval`) run end-to-end through
//!   `CompressEngine::from_file` exactly like `angelslim compress` would,
//!   producing a per-stage `PipelineReport`;
//! * the smooth pass is function-preserving and actually helps GPTQ;
//! * the `--json` report line round-trips through the JSON parser;
//! * the registry is the single source of truth (listing == dispatch).

use angelslim::config::{Json, SlimConfig};
use angelslim::coordinator::{CompressEngine, PassKind, PassRegistry, SlimFactory};

const DATASET: &str = "dataset:\n  kind: fixture\n  num_samples: 8\n  seq_len: 40\n";

fn legacy_src(method: &str, algo: &str, overrides: &str) -> String {
    format!(
        "global:\n  save_path: target/test-output/pass_pipeline\n  seed: 7\n\
         model:\n  name: tiny-fixture\n\
         compression:\n  method: {method}\n  {method}:\n    algo: {algo}\n{overrides}{DATASET}"
    )
}

fn pipeline_src(pass: &str, stage_overrides: &str) -> String {
    format!(
        "global:\n  save_path: target/test-output/pass_pipeline\n  seed: 7\n\
         model:\n  name: tiny-fixture\n\
         pipeline:\n  - pass: {pass}\n{stage_overrides}{DATASET}"
    )
}

fn run(src: &str) -> (angelslim::coordinator::PipelineReport, Option<Vec<u32>>) {
    let engine = CompressEngine::new(SlimConfig::from_str(src).unwrap()).unwrap();
    let (report, ctx) = engine.run_with_context().unwrap();
    let bits = ctx
        .into_model()
        .map(|m| m.flat_weights().into_iter().map(f32::to_bits).collect());
    (report, bits)
}

/// A one-stage pipeline must be bit-identical to the equivalent legacy
/// single-method config: same model bytes, same report numbers (wall-clock
/// excluded — the only non-deterministic field).
#[test]
fn one_stage_pipeline_is_bit_identical_to_legacy_form() {
    const LOW_MEM: &str = "    low_memory_budget_layers: 1\n";
    let cases: &[(&str, &str, &str, &str)] = &[
        // method, algo, legacy overrides (method-section), stage overrides
        ("quantization", "int4", "", ""),
        ("quantization", "gptq", LOW_MEM, LOW_MEM),
        ("token_prune", "idpruner", "    ratio: 0.25\n", "    ratio: 0.25\n"),
        ("sparse_attn", "stem", "    ratio: 0.3\n", "    ratio: 0.3\n"),
    ];
    for (method, algo, legacy_over, stage_over) in cases {
        let (legacy, legacy_model) = run(&legacy_src(method, algo, legacy_over));
        let (piped, piped_model) = run(&pipeline_src(algo, stage_over));
        assert_eq!(legacy.stages.len(), 1, "{algo}");
        assert_eq!(piped.stages.len(), 1, "{algo}");
        assert!(
            legacy.stages[0].same_numbers(&piped.stages[0]),
            "{algo}: report numbers diverged\n legacy: {:?}\n piped: {:?}",
            legacy.stages[0],
            piped.stages[0]
        );
        assert_eq!(
            legacy_model, piped_model,
            "{algo}: pipeline form must produce bit-identical model weights"
        );
        // quant passes mutate a loaded model; prune never loads one
        match *method {
            "quantization" => assert!(legacy_model.is_some(), "{algo}"),
            "token_prune" => assert!(legacy_model.is_none(), "{algo} must stay model-free"),
            _ => {}
        }
    }
}

/// Determinism backstop for the equivalence test: the same config run
/// twice produces the same report numbers and model bits.
#[test]
fn pipeline_runs_are_deterministic() {
    let src = pipeline_src("gptq", "");
    let (a, ma) = run(&src);
    let (b, mb) = run(&src);
    assert!(a.stages[0].same_numbers(&b.stages[0]));
    assert_eq!(ma, mb);
}

#[test]
fn shipped_smooth_gptq_eval_config_runs_end_to_end() {
    let engine = CompressEngine::from_file("configs/pipeline_smooth_gptq_fixture.yaml").unwrap();
    let (report, ctx) = engine.run_with_context().unwrap();
    assert_eq!(report.stages.len(), 3);
    let [smooth, gptq, eval] = &report.stages[..] else { unreachable!() };

    assert_eq!((smooth.pass.as_str(), smooth.kind.as_str()), ("smooth", "quantization"));
    // migration is function-preserving: NLL moves only by float rounding
    assert!(
        (smooth.metric_after - smooth.metric_before).abs() < 0.05,
        "smooth must not change the function: {smooth:?}"
    );
    assert!((smooth.size_ratio - 1.0).abs() < 1e-12, "{smooth:?}");

    assert_eq!(gptq.pass, "gptq");
    assert!(gptq.peak_calib_bytes > 0, "low-memory ledger must report: {gptq:?}");
    assert!(
        gptq.metric_after < gptq.metric_before + 0.8,
        "gptq on the smoothed model must not collapse: {gptq:?}"
    );
    assert!((gptq.size_ratio - 5.0 / 32.0).abs() < 1e-12);

    assert_eq!((eval.pass.as_str(), eval.kind.as_str()), ("eval", "eval"));
    // the checkpoint scores the final model against the pipeline baseline
    assert_eq!(eval.metric_before.to_bits(), ctx.baseline_nll.unwrap().to_bits());
    assert_eq!(eval.metric_after.to_bits(), gptq.metric_after.to_bits());
    assert!(eval.notes.iter().any(|n| n.contains("ppl")), "{eval:?}");

    assert!((report.overall_size_ratio() - 5.0 / 32.0).abs() < 1e-12);
    assert!(report.total_wall_ms() >= 0.0);
    assert_eq!(report.final_stage().pass, "eval");
}

#[test]
fn shipped_prune_int4_eval_config_runs_end_to_end() {
    let engine = CompressEngine::from_file("configs/pipeline_prune_int4_fixture.yaml").unwrap();
    let (report, ctx) = engine.run_with_context().unwrap();
    assert_eq!(report.stages.len(), 3);
    let [prune, int4, eval] = &report.stages[..] else { unreachable!() };

    assert_eq!((prune.pass.as_str(), prune.kind.as_str()), ("idpruner", "token_prune"));
    assert!(prune.metric_after > 0.3, "pruned VQA accuracy collapsed: {prune:?}");
    assert!((prune.size_ratio - 0.25).abs() < 1e-12, "{prune:?}");

    assert_eq!(int4.pass, "int4");
    assert!(int4.metric_after < int4.metric_before + 0.6, "{int4:?}");

    assert_eq!(eval.pass, "eval");
    assert_eq!(eval.metric_after.to_bits(), int4.metric_after.to_bits());
    // prune produced no NLL, so the baseline is int4's pristine before
    assert_eq!(ctx.baseline_nll.unwrap().to_bits(), int4.metric_before.to_bits());

    // combined footprint: 0.25 tokens kept x 5/32 weight bits
    assert!((report.overall_size_ratio() - 0.25 * 5.0 / 32.0).abs() < 1e-12);
}

/// SmoothQuant migration must measurably condition the weights: the
/// migrated model's weight channels are flatter, and GPTQ after smooth is
/// no worse than a meaningful margin vs GPTQ alone.
#[test]
fn smooth_stage_composes_with_gptq() {
    let solo = run(&pipeline_src("gptq", "")).0;
    let chained_src = format!(
        "global:\n  save_path: target/test-output/pass_pipeline\n  seed: 7\n\
         model:\n  name: tiny-fixture\n\
         pipeline:\n  - smooth\n  - gptq\n{DATASET}"
    );
    let (chained, _) = run(&chained_src);
    let solo_after = solo.stages[0].metric_after;
    let chained_after = chained.stages[1].metric_after;
    assert!(
        chained_after < solo_after + 0.3,
        "smooth->gptq {chained_after} must stay comparable to gptq {solo_after}"
    );
}

#[test]
fn json_report_line_round_trips() {
    let engine = CompressEngine::from_file("configs/pipeline_smooth_gptq_fixture.yaml").unwrap();
    let report = engine.run().unwrap();
    let line = report.to_json("configs/pipeline_smooth_gptq_fixture.yaml");
    let v = Json::parse(&line).expect("compress --json line must be valid JSON");
    assert_eq!(v.get("bench").unwrap().as_str(), Some("compress"));
    let stages = v.get("stages").unwrap();
    assert_eq!(stages.idx(2).unwrap().get("pass").unwrap().as_str(), Some("eval"));
    for i in 0..3 {
        let s = stages.idx(i).unwrap();
        for key in ["metric_before", "metric_after", "compression", "size_ratio", "wall_ms"] {
            assert!(s.get(key).unwrap().as_f64().is_some(), "stage {i} missing {key}");
        }
    }
    assert!(v.get("overall_size_ratio").unwrap().as_f64().is_some());
}

/// The registry is the single source of truth: the factory listing, the
/// schema's accepted names, and the engine's dispatch all agree.
#[test]
fn registry_is_single_source_of_truth() {
    // listing == registry
    let listed: Vec<&str> = SlimFactory::registered()
        .into_iter()
        .flat_map(|(_, algos)| algos)
        .collect();
    assert_eq!(listed.len(), PassRegistry::all().len());
    // every listed name parses as a one-stage pipeline (schema agrees)...
    for name in &listed {
        let src = pipeline_src(name, "");
        let cfg = SlimConfig::from_str(&src)
            .unwrap_or_else(|e| panic!("registered pass `{name}` rejected by schema: {e:#}"));
        // ...and the engine resolves it (dispatch agrees)
        CompressEngine::new(cfg)
            .unwrap_or_else(|e| panic!("registered pass `{name}` rejected by engine: {e:#}"));
    }
    // every method family default is registered under that family
    for kind in PassKind::all() {
        let p = PassRegistry::find(kind.default_pass()).expect("default must be registered");
        assert_eq!(p.kind(), kind);
    }
}

/// Calibrated passes consume `group_size`, and a group that cannot tile
/// the model's rows is a loud prepare-stage error — never a silent
/// fall-back to the default.
#[test]
fn gptq_group_override_is_wired_and_guarded() {
    let (r32, m32) = run(&pipeline_src("gptq", "    group_size: 32\n"));
    let (r16, m16) = run(&pipeline_src("gptq", "    group_size: 16\n"));
    assert!(r32.stages[0].metric_after.is_finite() && r16.stages[0].metric_after.is_finite());
    assert_ne!(m32, m16, "finer groups must change the reconstruction");
    // 24 does not divide the fixture's d_model = 32
    let cfg = SlimConfig::from_str(&pipeline_src("gptq", "    group_size: 24\n")).unwrap();
    let err = CompressEngine::new(cfg).unwrap().run().unwrap_err();
    assert!(format!("{err:#}").contains("group_size"), "{err:#}");
}

/// Reported compression derives from the quantizer that actually ran, so
/// per-stage overrides stay in lockstep with the size accounting — and a
/// group that cannot tile every weight row is a loud error, not a kernel
/// assert (the fixture's d_model is 32, so 64 fits no attention row).
#[test]
fn w4a8_compression_tracks_group_size_override() {
    let (r32, _) = run(&pipeline_src("w4a8", ""));
    assert!((r32.stages[0].compression - 5.0).abs() < 1e-12, "group 32: {:?}", r32.stages[0]);
    let (r16, _) = run(&pipeline_src("w4a8", "    group_size: 16\n"));
    assert!((r16.stages[0].compression - 6.0).abs() < 1e-12, "group 16: {:?}", r16.stages[0]);
    assert!(
        r16.stages[0].size_ratio > r32.stages[0].size_ratio,
        "finer groups carry more scale overhead"
    );
    let cfg = SlimConfig::from_str(&pipeline_src("w4a8", "    group_size: 64\n")).unwrap();
    let err = CompressEngine::new(cfg).unwrap().run().unwrap_err();
    assert!(format!("{err:#}").contains("group_size"), "{err:#}");
}

/// Newly wrapped QAT-side quantizers run as pipeline passes: tequila and
/// sherry QDQ the fixture end to end with their expected footprints.
#[test]
fn tequila_and_sherry_run_as_passes() {
    for (pass, bits) in [("tequila", 2.0), ("sherry", 1.25)] {
        let (report, model) = run(&pipeline_src(pass, ""));
        let s = &report.stages[0];
        assert_eq!(s.pass, pass);
        assert!((s.compression - bits).abs() < 1e-12, "{s:?}");
        // sub-2-bit PTQ visibly damages the planted rule (sanity that the
        // quantizer actually ran)
        assert!(s.metric_after > s.metric_before, "{s:?}");
        assert!(model.is_some());
    }
}
