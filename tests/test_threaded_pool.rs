//! Cross-mode determinism properties for the OS-thread worker pool
//! (`serve.threads`): at 1, 2, and 4 threads, the threaded pool must
//! produce per-request outputs, generated-token counts, terminal outcome
//! kinds, and total-token accounting **identical** to the single-thread
//! virtual-clock twin on the same trace — fault-free and under seeded
//! chaos (step errors, poisoned logits, worker crashes, stalls against
//! deadlines). Only wall-clock-derived fields (`wall_s`, `tps`, TTFT and
//! latency percentiles, in-flight samples, peak KV residency) may differ
//! between modes; everything a caller can act on is bit-stable.
//!
//! The suite runs on any machine: thread-count parity is a correctness
//! claim, not a performance one, so nothing here is gated on core count
//! (the ≥1.5x wall-clock scaling gate lives in `bench_sharded`).

use angelslim::data::{RequestGen, TokenRequest};
use angelslim::models::Transformer;
use angelslim::server::{
    ClassPolicy, ClassSlo, FaultPlan, RequestClass, RequestOutcome, ServeCfg, ServeReport,
    ServingEngine,
};
use angelslim::util::fixtures::{
    fixture_corpus, fixture_draft, fixture_target, FixtureSpec,
};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, assert_terminal_outcomes,
    fixture_requests,
};

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn run(
    reqs: Vec<TokenRequest>,
    target: &Transformer,
    cfg: &ServeCfg,
) -> ServeReport {
    ServingEngine::serve_scheduled::<Transformer, _>(reqs, target, None, cfg, 0).unwrap()
}

/// Outcome *kind*, ignoring the `Failed` error text: failure messages
/// name the worker index that contained the fault, and which worker that
/// is legitimately differs between the virtual schedule and a real
/// thread race.
fn kind(o: &RequestOutcome) -> &'static str {
    match o {
        RequestOutcome::Completed => "completed",
        RequestOutcome::Failed { .. } => "failed",
        RequestOutcome::DeadlineExceeded => "deadline_exceeded",
        RequestOutcome::Shed => "shed",
    }
}

/// The cross-mode determinism contract: same ids in the same order, same
/// outputs and generated counts, same outcome kinds, same pool-wide token
/// total.
fn assert_modes_agree(twin: &ServeReport, threaded: &ServeReport, context: &str) {
    assert_outputs_match(twin, threaded, context);
    assert_eq!(
        twin.total_tokens, threaded.total_tokens,
        "{context}: pool-wide token accounting diverged"
    );
    for (a, b) in twin.completed.iter().zip(&threaded.completed) {
        assert_eq!(a.id, b.id, "{context}: terminal ids misaligned");
        assert_eq!(
            kind(&a.outcome),
            kind(&b.outcome),
            "{context}: request {} outcome kind diverged",
            a.id
        );
    }
}

#[test]
fn threaded_outputs_bit_identical_to_twin_fault_free() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 51);
    let target = fixture_target(5);
    let n = 10;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4).with_workers(threads);
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.with_threads(true));
        assert_serving_contracts(&twin, n, 0);
        assert_serving_contracts(&live, n, 0);
        assert_eq!(live.workers(), threads);
        assert_modes_agree(&twin, &live, &format!("fault-free, {threads} threads"));
    }
}

#[test]
fn threaded_speculative_decoding_matches_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 53);
    let target = fixture_target(3);
    let draft = fixture_draft(3);
    let n = 8;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4).with_workers(threads);
        let twin = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg.clone().with_threads(false),
            0,
        )
        .unwrap();
        let live = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg.with_threads(true),
            0,
        )
        .unwrap();
        assert_serving_contracts(&live, n, 0);
        let context = format!("speculative, {threads} threads");
        assert_modes_agree(&twin, &live, &context);
        // each request's verify schedule is interleaving-independent, so
        // speculation bookkeeping must agree across modes too
        assert_eq!(twin.proposed, live.proposed, "{context}: proposed");
        assert_eq!(twin.accepted, live.accepted, "{context}: accepted");
    }
}

/// Step errors and poisoned logits draw per (request, attempt, round),
/// never per worker or per schedule — so under the same plan the exact
/// same requests fault, retry the same number of times, and reach the
/// same terminal outcome in both modes at every thread count.
#[test]
fn seeded_chaos_outcomes_match_twin_at_every_thread_count() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 57);
    let target = fixture_target(5);
    let n = 9;
    let reqs = || fixture_requests(&corpus, n, 12);
    let plan = FaultPlan::default().seeded(23).with_step_errors(0.08).with_nan(0.04);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4)
            .with_workers(threads)
            .with_retries(2)
            .with_backoff(0.25)
            .with_faults(plan.clone());
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.with_threads(true));
        assert_terminal_outcomes(&twin, n, 0);
        assert_terminal_outcomes(&live, n, 0);
        let context = format!("step-error/nan chaos, {threads} threads");
        assert_modes_agree(&twin, &live, &context);
        for (a, b) in twin.completed.iter().zip(&live.completed) {
            assert_eq!(
                a.attempts, b.attempts,
                "{context}: request {} attempt count diverged",
                a.id
            );
        }
    }
    // the profile must actually inject something, or this proves nothing
    let probe = run(
        reqs(),
        &target,
        &ServeCfg::continuous(4)
            .with_retries(2)
            .with_backoff(0.25)
            .with_faults(plan),
    );
    assert!(probe.retried() > 0, "chaos profile injected nothing; raise the rates");
}

/// A worker crash in threaded mode is a real thread death: the pool
/// contains it, survivors absorb the requeued live set, and the
/// request-level result is identical to the twin's virtual crash.
#[test]
fn crash_containment_matches_twin_request_for_request() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 59);
    let target = fixture_target(5);
    let n = 8;
    let reqs = || fixture_requests(&corpus, n, 12);
    let cfg = ServeCfg::continuous(2)
        .with_workers(2)
        .with_retries(3)
        .with_backoff(0.1)
        .with_faults(FaultPlan::default().with_crash(1, 0.0));

    let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
    assert_terminal_outcomes(&twin, n, 0);
    assert_eq!(twin.goodput(), n, "twin: survivor absorbs the crashed worker");
    assert_eq!(twin.crashed_workers.len(), 1);
    assert_eq!(twin.crashed_workers[0].0, 1);

    let live = run(reqs(), &target, &cfg.with_threads(true));
    assert_terminal_outcomes(&live, n, 0);
    assert_eq!(live.goodput(), n, "threaded: survivor absorbs the dead thread's load");
    // the crash fires on worker 1's first decode round; under a real
    // thread race worker 1 may never win a round before the queue drains,
    // so the count is <= 1 — but it can never be any other worker
    assert!(live.crashed_workers.len() <= 1);
    assert!(live.crashed_workers.iter().all(|c| c.0 == 1));
    assert_modes_agree(&twin, &live, "crash chaos, 2 threads");
}

/// Stalls against a tight deadline: every request must be cancelled —
/// mid-flight or before admission — in both modes, with exactly-once
/// accounting. (Partial-output sizes are timing-dependent under
/// deadlines, so this asserts outcome kinds, not outputs.)
#[test]
fn stalled_deadline_cancellations_match_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 61);
    let target = fixture_target(5);
    let n = 6;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in [1usize, 2] {
        let cfg = ServeCfg::continuous(4)
            .with_workers(threads)
            .with_deadline(1.0)
            .with_faults(FaultPlan::default().with_stalls(1.0, 50.0));
        for threaded in [false, true] {
            let r = run(reqs(), &target, &cfg.clone().with_threads(threaded));
            assert_terminal_outcomes(&r, n, 0);
            assert!(
                r.completed
                    .iter()
                    .all(|c| c.outcome == RequestOutcome::DeadlineExceeded),
                "threads={threads} threaded={threaded}: a 50 ms stall every round \
                 must push every request past a 1 ms deadline: {:?}",
                r.outcome_counts()
            );
        }
    }
}

/// A class policy whose SLO thresholds are astronomically loose, so the
/// per-class attainment counters are timing-independent (every completed
/// request attains both SLOs) and can be compared bit-for-bit across
/// modes and thread counts.
fn huge_slo_policy() -> ClassPolicy {
    let mut p = ClassPolicy::default();
    for slo in [
        &mut p.interactive,
        &mut p.long_context,
        &mut p.multimodal,
        &mut p.batch,
    ] {
        slo.ttft_slo_ms = 1e12;
        slo.latency_slo_ms = 1e12;
    }
    p
}

/// Mixed-class chaos trace: the class subsystem composes with fault
/// injection — per-class terminal outcome kinds, attempt counts, and SLO
/// counters (under timing-independent thresholds) are bit-identical
/// between the virtual-clock twin and the threaded pool at 1/2/4
/// threads, and the compression routing fires identically in both modes.
#[test]
fn mixed_class_chaos_outcomes_and_slo_counters_match_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 8_192, 67);
    let target = fixture_target(5);
    let reqs = || {
        let mut gen = RequestGen::new(corpus.clone(), 7);
        gen.prompt_len = 6;
        gen.max_new_tokens = 8;
        gen.take_mixed_classes(2, 5, 1.0, 24, 8, 4)
    };
    let n = reqs().len();
    let policy = huge_slo_policy();
    let plan = FaultPlan::default().seeded(29).with_step_errors(0.08).with_nan(0.04);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(2)
            .with_workers(threads)
            .with_retries(2)
            .with_backoff(0.25)
            .with_classes(policy.clone())
            .with_faults(plan.clone());
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.with_threads(true));
        assert_terminal_outcomes(&twin, n, 0);
        assert_terminal_outcomes(&live, n, 0);
        let context = format!("mixed-class chaos, {threads} threads");
        assert_modes_agree(&twin, &live, &context);
        for (a, b) in twin.completed.iter().zip(&live.completed) {
            assert_eq!(a.class, b.class, "{context}: request {} class diverged", a.id);
            assert_eq!(
                a.attempts, b.attempts,
                "{context}: request {} attempt count diverged",
                a.id
            );
        }
        // per-class SLO counters are part of the determinism contract
        for (t, l) in twin
            .class_breakdown(&policy)
            .iter()
            .zip(&live.class_breakdown(&policy))
        {
            assert_eq!(t.name, l.name);
            assert_eq!(t.counts, l.counts, "{context}: class {} outcome counts", t.name);
            assert_eq!(
                t.ttft_attained, l.ttft_attained,
                "{context}: class {} TTFT attainment",
                t.name
            );
            assert_eq!(
                t.latency_attained, l.latency_attained,
                "{context}: class {} latency attainment",
                t.name
            );
        }
        // routing is schedule-independent: same sparse prefill count and
        // the same pruned-token total in both modes
        assert_eq!(twin.sparse_prefills, live.sparse_prefills, "{context}: sparse prefills");
        assert_eq!(
            twin.pruned_prompt_tokens, live.pruned_prompt_tokens,
            "{context}: pruned prompt tokens"
        );
        assert!(twin.sparse_prefills > 0, "{context}: LongContext must route sparse");
        assert!(twin.pruned_prompt_tokens > 0, "{context}: Multimodal must be pruned");
    }
}

/// The aging bound is a hard starvation ceiling, pinned from both sides
/// on the deterministic twin's admission log: with `aging_ms: 0` every
/// queued request competes at max priority immediately, so admission
/// degenerates to FIFO and the batch request (first arrival) seats
/// first; with an astronomically large bound, priorities rule and the
/// batch request seats after every interactive despite arriving first.
#[test]
fn aging_bound_prevents_and_pins_batch_starvation() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 71);
    let target = fixture_target(5);
    let reqs = || {
        let mut v = vec![TokenRequest {
            id: 0,
            prompt: corpus[..6].to_vec(),
            max_new_tokens: 4,
            arrival_ms: 0.0,
            deadline_ms: None,
            class: RequestClass::Batch,
        }];
        for i in 1..=3u64 {
            v.push(TokenRequest {
                id: i,
                prompt: corpus[6 * i as usize..6 * i as usize + 6].to_vec(),
                max_new_tokens: 4,
                arrival_ms: 0.0,
                deadline_ms: None,
                class: RequestClass::Interactive,
            });
        }
        v
    };

    // one worker, one slot: admissions fully serialize, so the admission
    // log is the priority order
    let base = ServeCfg::continuous(1).with_workers(1);

    let mut fifo_policy = huge_slo_policy();
    fifo_policy.aging_ms = 0.0;
    let fifo = run(reqs(), &target, &base.clone().with_classes(fifo_policy));
    assert_eq!(
        fifo.admitted_order,
        vec![0, 1, 2, 3],
        "aging_ms=0: everything competes at max priority, FIFO decides"
    );

    let mut strict_policy = huge_slo_policy();
    strict_policy.aging_ms = 1e12;
    let strict = run(reqs(), &target, &base.with_classes(strict_policy));
    assert_eq!(
        strict.admitted_order,
        vec![1, 2, 3, 0],
        "un-aged priorities must seat every interactive before batch"
    );
    assert_eq!(strict.goodput(), 4, "batch still completes — bounded, not starved");
}

/// Deadline precedence, pinned end to end: per-request `deadline_ms`
/// beats the per-class default, which beats the pool-wide
/// `serve.deadline_ms` (documented on `ServeCfg::deadline_ms`).
#[test]
fn deadline_precedence_request_beats_class_beats_pool() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 73);
    let target = fixture_target(5);
    let req = |id: u64, class: RequestClass, deadline_ms: Option<f64>| TokenRequest {
        id,
        prompt: corpus[id as usize * 7..id as usize * 7 + 6].to_vec(),
        max_new_tokens: 4,
        arrival_ms: 0.0,
        deadline_ms,
        class,
    };
    // every decode round stalls 50 virtual ms, so a sub-ms deadline
    // always fires and a huge one never does
    let stall = FaultPlan::default().with_stalls(1.0, 50.0);

    // class default beats the pool-wide deadline: batch carries a huge
    // class deadline, interactive has none and falls to the tiny pool one
    let mut policy = huge_slo_policy();
    policy.batch.deadline_ms = Some(1e9);
    let r = run(
        vec![
            req(0, RequestClass::Batch, None),
            req(1, RequestClass::Interactive, None),
        ],
        &target,
        &ServeCfg::continuous(4)
            .with_classes(policy)
            .with_deadline(0.5)
            .with_faults(stall.clone()),
    );
    assert_eq!(r.completed[0].outcome, RequestOutcome::Completed, "class > pool");
    assert_eq!(
        r.completed[1].outcome,
        RequestOutcome::DeadlineExceeded,
        "no class deadline -> pool-wide applies"
    );

    // per-request beats the class default: both batch, tiny class
    // deadline, one request overrides it with a huge per-request one
    let mut policy = huge_slo_policy();
    policy.batch.deadline_ms = Some(0.5);
    let r = run(
        vec![
            req(0, RequestClass::Batch, Some(1e9)),
            req(1, RequestClass::Batch, None),
        ],
        &target,
        &ServeCfg::continuous(4).with_classes(policy).with_faults(stall),
    );
    assert_eq!(r.completed[0].outcome, RequestOutcome::Completed, "request > class");
    assert_eq!(
        r.completed[1].outcome,
        RequestOutcome::DeadlineExceeded,
        "unset per-request deadline -> class default applies"
    );

    // sanity: ClassSlo::new leaves the class deadline unset by default
    assert_eq!(ClassSlo::new(1.0, 2.0, 0).deadline_ms, None);
}

/// KV admission budgets hold in threaded mode: per-worker shares are
/// enforced by the same `has_room` arithmetic, so pool-wide peak live KV
/// stays within the budget while every request still completes with
/// twin-identical output.
#[test]
fn threaded_pool_respects_kv_budget_shares() {
    use angelslim::util::testing::projected_greedy_bytes as projected_greedy;
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 63);
    let target = fixture_target(5);
    let n = 12;
    let reqs = || fixture_requests(&corpus, n, 12);
    let worst = reqs().iter().map(|r| projected_greedy(&target, r)).max().unwrap();

    for threads in [2usize, 4] {
        let cfg = ServeCfg::continuous(8)
            .with_workers(threads)
            .with_budget(threads * (2 * worst + 64));
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.clone().with_threads(true));
        assert_serving_contracts(&twin, n, cfg.kv_budget_bytes);
        assert_serving_contracts(&live, n, cfg.kv_budget_bytes);
        assert_modes_agree(&twin, &live, &format!("budgeted, {threads} threads"));
        let shares = cfg.per_worker_budgets();
        for (w, peak) in live.worker_peak_kv_bytes.iter().enumerate() {
            assert!(
                *peak <= shares[w],
                "threads={threads}: worker {w} peak {peak} exceeded share {}",
                shares[w]
            );
        }
    }
}
