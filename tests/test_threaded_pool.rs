//! Cross-mode determinism properties for the OS-thread worker pool
//! (`serve.threads`): at 1, 2, and 4 threads, the threaded pool must
//! produce per-request outputs, generated-token counts, terminal outcome
//! kinds, and total-token accounting **identical** to the single-thread
//! virtual-clock twin on the same trace — fault-free and under seeded
//! chaos (step errors, poisoned logits, worker crashes, stalls against
//! deadlines). Only wall-clock-derived fields (`wall_s`, `tps`, TTFT and
//! latency percentiles, in-flight samples, peak KV residency) may differ
//! between modes; everything a caller can act on is bit-stable.
//!
//! The suite runs on any machine: thread-count parity is a correctness
//! claim, not a performance one, so nothing here is gated on core count
//! (the ≥1.5x wall-clock scaling gate lives in `bench_sharded`).

use angelslim::data::TokenRequest;
use angelslim::models::Transformer;
use angelslim::server::{
    FaultPlan, RequestOutcome, ServeCfg, ServeReport, ServingEngine,
};
use angelslim::util::fixtures::{
    fixture_corpus, fixture_draft, fixture_target, FixtureSpec,
};
use angelslim::util::testing::{
    assert_outputs_match, assert_serving_contracts, assert_terminal_outcomes,
    fixture_requests,
};

const THREAD_COUNTS: [usize; 3] = [1, 2, 4];

fn run(
    reqs: Vec<TokenRequest>,
    target: &Transformer,
    cfg: &ServeCfg,
) -> ServeReport {
    ServingEngine::serve_scheduled::<Transformer, _>(reqs, target, None, cfg, 0).unwrap()
}

/// Outcome *kind*, ignoring the `Failed` error text: failure messages
/// name the worker index that contained the fault, and which worker that
/// is legitimately differs between the virtual schedule and a real
/// thread race.
fn kind(o: &RequestOutcome) -> &'static str {
    match o {
        RequestOutcome::Completed => "completed",
        RequestOutcome::Failed { .. } => "failed",
        RequestOutcome::DeadlineExceeded => "deadline_exceeded",
        RequestOutcome::Shed => "shed",
    }
}

/// The cross-mode determinism contract: same ids in the same order, same
/// outputs and generated counts, same outcome kinds, same pool-wide token
/// total.
fn assert_modes_agree(twin: &ServeReport, threaded: &ServeReport, context: &str) {
    assert_outputs_match(twin, threaded, context);
    assert_eq!(
        twin.total_tokens, threaded.total_tokens,
        "{context}: pool-wide token accounting diverged"
    );
    for (a, b) in twin.completed.iter().zip(&threaded.completed) {
        assert_eq!(a.id, b.id, "{context}: terminal ids misaligned");
        assert_eq!(
            kind(&a.outcome),
            kind(&b.outcome),
            "{context}: request {} outcome kind diverged",
            a.id
        );
    }
}

#[test]
fn threaded_outputs_bit_identical_to_twin_fault_free() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 51);
    let target = fixture_target(5);
    let n = 10;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4).with_workers(threads);
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.with_threads(true));
        assert_serving_contracts(&twin, n, 0);
        assert_serving_contracts(&live, n, 0);
        assert_eq!(live.workers(), threads);
        assert_modes_agree(&twin, &live, &format!("fault-free, {threads} threads"));
    }
}

#[test]
fn threaded_speculative_decoding_matches_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 53);
    let target = fixture_target(3);
    let draft = fixture_draft(3);
    let n = 8;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4).with_workers(threads);
        let twin = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg.clone().with_threads(false),
            0,
        )
        .unwrap();
        let live = ServingEngine::serve_scheduled(
            reqs(),
            &target,
            Some((&draft, 3)),
            &cfg.with_threads(true),
            0,
        )
        .unwrap();
        assert_serving_contracts(&live, n, 0);
        let context = format!("speculative, {threads} threads");
        assert_modes_agree(&twin, &live, &context);
        // each request's verify schedule is interleaving-independent, so
        // speculation bookkeeping must agree across modes too
        assert_eq!(twin.proposed, live.proposed, "{context}: proposed");
        assert_eq!(twin.accepted, live.accepted, "{context}: accepted");
    }
}

/// Step errors and poisoned logits draw per (request, attempt, round),
/// never per worker or per schedule — so under the same plan the exact
/// same requests fault, retry the same number of times, and reach the
/// same terminal outcome in both modes at every thread count.
#[test]
fn seeded_chaos_outcomes_match_twin_at_every_thread_count() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 57);
    let target = fixture_target(5);
    let n = 9;
    let reqs = || fixture_requests(&corpus, n, 12);
    let plan = FaultPlan::default().seeded(23).with_step_errors(0.08).with_nan(0.04);

    for threads in THREAD_COUNTS {
        let cfg = ServeCfg::continuous(4)
            .with_workers(threads)
            .with_retries(2)
            .with_backoff(0.25)
            .with_faults(plan.clone());
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.with_threads(true));
        assert_terminal_outcomes(&twin, n, 0);
        assert_terminal_outcomes(&live, n, 0);
        let context = format!("step-error/nan chaos, {threads} threads");
        assert_modes_agree(&twin, &live, &context);
        for (a, b) in twin.completed.iter().zip(&live.completed) {
            assert_eq!(
                a.attempts, b.attempts,
                "{context}: request {} attempt count diverged",
                a.id
            );
        }
    }
    // the profile must actually inject something, or this proves nothing
    let probe = run(
        reqs(),
        &target,
        &ServeCfg::continuous(4)
            .with_retries(2)
            .with_backoff(0.25)
            .with_faults(plan),
    );
    assert!(probe.retried() > 0, "chaos profile injected nothing; raise the rates");
}

/// A worker crash in threaded mode is a real thread death: the pool
/// contains it, survivors absorb the requeued live set, and the
/// request-level result is identical to the twin's virtual crash.
#[test]
fn crash_containment_matches_twin_request_for_request() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 59);
    let target = fixture_target(5);
    let n = 8;
    let reqs = || fixture_requests(&corpus, n, 12);
    let cfg = ServeCfg::continuous(2)
        .with_workers(2)
        .with_retries(3)
        .with_backoff(0.1)
        .with_faults(FaultPlan::default().with_crash(1, 0.0));

    let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
    assert_terminal_outcomes(&twin, n, 0);
    assert_eq!(twin.goodput(), n, "twin: survivor absorbs the crashed worker");
    assert_eq!(twin.crashed_workers.len(), 1);
    assert_eq!(twin.crashed_workers[0].0, 1);

    let live = run(reqs(), &target, &cfg.with_threads(true));
    assert_terminal_outcomes(&live, n, 0);
    assert_eq!(live.goodput(), n, "threaded: survivor absorbs the dead thread's load");
    // the crash fires on worker 1's first decode round; under a real
    // thread race worker 1 may never win a round before the queue drains,
    // so the count is <= 1 — but it can never be any other worker
    assert!(live.crashed_workers.len() <= 1);
    assert!(live.crashed_workers.iter().all(|c| c.0 == 1));
    assert_modes_agree(&twin, &live, "crash chaos, 2 threads");
}

/// Stalls against a tight deadline: every request must be cancelled —
/// mid-flight or before admission — in both modes, with exactly-once
/// accounting. (Partial-output sizes are timing-dependent under
/// deadlines, so this asserts outcome kinds, not outputs.)
#[test]
fn stalled_deadline_cancellations_match_twin() {
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 61);
    let target = fixture_target(5);
    let n = 6;
    let reqs = || fixture_requests(&corpus, n, 12);

    for threads in [1usize, 2] {
        let cfg = ServeCfg::continuous(4)
            .with_workers(threads)
            .with_deadline(1.0)
            .with_faults(FaultPlan::default().with_stalls(1.0, 50.0));
        for threaded in [false, true] {
            let r = run(reqs(), &target, &cfg.clone().with_threads(threaded));
            assert_terminal_outcomes(&r, n, 0);
            assert!(
                r.completed
                    .iter()
                    .all(|c| c.outcome == RequestOutcome::DeadlineExceeded),
                "threads={threads} threaded={threaded}: a 50 ms stall every round \
                 must push every request past a 1 ms deadline: {:?}",
                r.outcome_counts()
            );
        }
    }
}

/// KV admission budgets hold in threaded mode: per-worker shares are
/// enforced by the same `has_room` arithmetic, so pool-wide peak live KV
/// stays within the budget while every request still completes with
/// twin-identical output.
#[test]
fn threaded_pool_respects_kv_budget_shares() {
    use angelslim::util::testing::projected_greedy_bytes as projected_greedy;
    let spec = FixtureSpec::default();
    let corpus = fixture_corpus(&spec, 2_048, 63);
    let target = fixture_target(5);
    let n = 12;
    let reqs = || fixture_requests(&corpus, n, 12);
    let worst = reqs().iter().map(|r| projected_greedy(&target, r)).max().unwrap();

    for threads in [2usize, 4] {
        let cfg = ServeCfg::continuous(8)
            .with_workers(threads)
            .with_budget(threads * (2 * worst + 64));
        let twin = run(reqs(), &target, &cfg.clone().with_threads(false));
        let live = run(reqs(), &target, &cfg.clone().with_threads(true));
        assert_serving_contracts(&twin, n, cfg.kv_budget_bytes);
        assert_serving_contracts(&live, n, cfg.kv_budget_bytes);
        assert_modes_agree(&twin, &live, &format!("budgeted, {threads} threads"));
        let shares = cfg.per_worker_budgets();
        for (w, peak) in live.worker_peak_kv_bytes.iter().enumerate() {
            assert!(
                *peak <= shares[w],
                "threads={threads}: worker {w} peak {peak} exceeded share {}",
                shares[w]
            );
        }
    }
}
